//! Process-backed rank pool: the shared-memory transport's executor.
//!
//! The paper's DPSNN ranks are OS *processes* exchanging spikes over
//! MPI. [`ProcPool`] reproduces that shape locally: `Network::build`
//! constructs every rank in the coordinator process (over the channel
//! transport, so construction collectives need no fork juggling), then
//! the pool forks one worker per rank. Each child inherits its rank's
//! [`RankProcess`] through copy-on-write fork, re-homes its
//! communicator onto the `mpi::shm` data rings (carrying the
//! construction-phase comm statistics over), and serves the same
//! [`Command`] protocol as the thread pool — commands arrive as
//! length-prefixed frames on a per-rank command ring, replies return
//! on a reply ring, and both sides run the shared
//! [`execute_command`] dispatcher.
//!
//! ## Parent-side state
//!
//! The parent keeps its (now pristine, construction-time) copy of
//! every `RankProcess`. Static topology queries (`expectations`,
//! synapse counts) answer from that copy without a round-trip; dynamic
//! state always rides on replies (`Snapshot`, `Report`). After
//! `recover` the pool re-forks from the pristine copy and the session
//! layer restores dynamic state from its last auto-checkpoint — the
//! same replay contract as the thread pool, hence bit-identical
//! recovery across backends (the chaos suite enforces this).
//!
//! ## Death detection
//!
//! A worker process can die without a word (`FaultMode::Die`, a real
//! crash, the OOM killer). The coordinator never blocks on a silent
//! ring: every blocking edge (command writes, reply collection)
//! interleaves `waitpid(WNOHANG)` checks. On a detected death the
//! coordinator drains any fully-buffered reply, then closes the dead
//! rank's outgoing data rings itself so peers blocked mid-collective
//! cascade out with the ordinary "hung up" panic — the root cause
//! reported upward names the dead rank and its wait status, never the
//! cascade.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use crate::engine::process::{FaultMode, RankProcess, DIE_MARKER};
use crate::engine::RankReport;
use crate::mpi::shm::{
    self, Backoff, FrameAcc, ShmCluster,
};
use crate::mpi::{panic_message, CommStats, RankComm};
use crate::util::timer::WallStopwatch;

use super::executor::{
    execute_command, merge_root_panic, CollectOut, Command, Reply,
};

/// The worker-process pool (see the module docs).
pub(crate) struct ProcPool {
    /// Parent-side rank state: pristine at construction time. Children
    /// own their forked copies; this copy answers static queries and
    /// seeds re-forks after `recover`.
    procs: Vec<RankProcess>,
    /// Construction-phase comm statistics, taken from the channel
    /// communicators the ranks were built over; every (re)forked child
    /// seeds its shm communicator with its rank's clone so
    /// `Report`/`finish` totals span both phases, as on one MPI rank.
    init_stats: Vec<CommStats>,
    shm: ShmCluster,
    /// Child pid per rank; 0 once reaped.
    pids: Vec<i32>,
    /// Incremental per-rank reply-frame readers (frames can exceed the
    /// ring capacity; reads must make progress across collect rounds).
    accs: Vec<FrameAcc>,
    /// Death verdicts noticed via `waitpid`, kept until `collect`
    /// folds them into a poisoning.
    dead_msgs: Vec<Option<String>>,
    watchdog_timeout_ms: Option<u64>,
    poisoned: Option<String>,
}

impl ProcPool {
    /// Take over already-constructed ranks and fork one worker process
    /// per rank. The channel communicators are drained of their
    /// construction statistics and dropped — the shm rings replace
    /// them.
    pub fn launch(
        pairs: Vec<(RankProcess, RankComm)>,
        watchdog_timeout_ms: Option<u64>,
    ) -> ProcPool {
        let mut procs = Vec::with_capacity(pairs.len());
        let mut init_stats = Vec::with_capacity(pairs.len());
        for (proc, mut comm) in pairs {
            init_stats.push(comm.take_stats());
            procs.push(proc);
        }
        let ranks = u32::try_from(procs.len()).expect("rank count fits u32");
        let mut pool = ProcPool {
            procs,
            init_stats,
            shm: ShmCluster::new(ranks),
            pids: Vec::new(),
            accs: Vec::new(),
            dead_msgs: Vec::new(),
            watchdog_timeout_ms,
            poisoned: None,
        };
        pool.fork_all();
        pool
    }

    pub fn ranks(&self) -> usize {
        self.procs.len()
    }

    pub fn poison_message(&self) -> Option<&str> {
        self.poisoned.as_deref()
    }

    /// Fork one worker per rank from the parent-side state. Children
    /// seed their fault-fire counters from the shared cells so a
    /// `max_fires`-exhausted fault stays spent across re-forks.
    fn fork_all(&mut self) {
        let n = self.procs.len();
        self.accs = (0..n).map(|_| FrameAcc::new()).collect();
        self.dead_msgs = (0..n).map(|_| None).collect();
        self.pids = Vec::with_capacity(n);
        let mut pids = Vec::with_capacity(n);
        for (rank, proc) in (0_u32..).zip(self.procs.iter_mut()) {
            let cluster = self.shm.clone();
            let stats = self.init_stats[rank as usize].clone();
            let pid = shm::spawn_worker(move || worker_process(rank, proc, &cluster, stats));
            pids.push(pid);
        }
        self.pids = pids;
    }

    /// Run `f` over the parent-side copy of every rank (static
    /// topology only — see the module docs).
    pub fn with_procs<R>(&self, mut f: impl FnMut(&RankProcess) -> R) -> Vec<R> {
        self.procs.iter().map(|p| f(p)).collect()
    }

    /// Per-rank reports. Healthy pool: a `Report` round-trip, so the
    /// numbers are the children's live metrics. Poisoned pool: degrade
    /// to the parent's construction-time view rather than fail — the
    /// session still wants a summary after a crash.
    pub fn reports(&mut self) -> Vec<RankReport> {
        if self.poisoned.is_none() {
            if let Ok(out) = self.dispatch_each(|_| Command::Report) {
                if out.reports.iter().all(Option::is_some) {
                    return out
                        .reports
                        .into_iter()
                        .map(|w| RankReport::from_wire(&w.expect("report present")))
                        .collect();
                }
            }
        }
        self.procs
            .iter_mut()
            .zip(self.init_stats.iter())
            .map(|(p, s)| p.report(s))
            .collect()
    }

    /// Send one command per rank (`make(rank)`) and collect the
    /// replies.
    pub fn dispatch_each(
        &mut self,
        mut make: impl FnMut(usize) -> Command,
    ) -> Result<CollectOut, String> {
        if let Some(msg) = &self.poisoned {
            return Err(format!("virtual cluster poisoned: {msg}"));
        }
        // dispatch to every rank even if one is already dead: its live
        // peers received commands and will block mid-collective on it,
        // and collect() owns the diagnosis/cascade machinery
        for rank in 0..self.procs.len() {
            let frame = codec::encode_command(&make(rank));
            self.write_cmd(rank, &frame);
        }
        self.collect()
    }

    /// Write one command frame, streaming through the ring capacity.
    /// Interleaves death checks: never blocks on a ring whose consumer
    /// is gone (the death itself is folded in by `collect`).
    fn write_cmd(&mut self, rank: usize, payload: &[u8]) {
        let ring = self.shm.cmd_ring(u32::try_from(rank).expect("rank fits u32"));
        let hdr = (u64::try_from(payload.len()).expect("frame length fits u64")).to_le_bytes();
        let mut buf = Vec::with_capacity(8 + payload.len());
        buf.extend_from_slice(&hdr);
        buf.extend_from_slice(payload);
        let mut off = 0usize;
        let mut backoff = Backoff::new();
        while off < buf.len() {
            let n = ring.write_some(&buf[off..]);
            if n > 0 {
                off += n;
                backoff.reset();
                continue;
            }
            self.check_death(rank);
            if self.dead_msgs[rank].is_some() {
                return; // collect() reports it; the partial frame is moot
            }
            backoff.snooze();
        }
    }

    /// One `waitpid(WNOHANG)` probe for `rank`, recording a death
    /// verdict (and reaping the zombie) at most once.
    fn check_death(&mut self, rank: usize) {
        if self.dead_msgs[rank].is_some() || self.pids[rank] == 0 {
            return;
        }
        if let Some(status) = shm::try_wait(self.pids[rank]) {
            self.pids[rank] = 0;
            self.dead_msgs[rank] = Some(death_message(rank, status));
        }
    }

    /// Wait for exactly one reply per rank, diagnosing silent worker
    /// deaths via `waitpid` and hangs via the watchdog deadline.
    fn collect(&mut self) -> Result<CollectOut, String> {
        let n = self.procs.len();
        let mut out = CollectOut::empty(n);
        let mut done = vec![false; n];
        let mut root: Option<String> = None;
        let mut sw = WallStopwatch::new();
        sw.start();
        let mut backoff = Backoff::new();
        while !done.iter().all(|d| *d) {
            let mut progressed = false;
            for rank in 0..n {
                if done[rank] {
                    continue;
                }
                let ring = self.shm.reply_ring(u32::try_from(rank).expect("rank fits u32"));
                let (nread, frame) = self.accs[rank].poll(&ring);
                progressed |= nread > 0;
                if let Some(bytes) = frame {
                    done[rank] = true;
                    progressed = true;
                    match codec::decode_reply(&bytes) {
                        Ok(Reply::Done { frames, state, report, .. }) => {
                            out.frames[rank] = frames;
                            out.states[rank] = state;
                            out.reports[rank] = report;
                        }
                        Ok(Reply::Panicked { msg, .. }) => {
                            merge_root_panic(&mut root, format!("rank {rank} panicked: {msg}"));
                        }
                        Err(e) => {
                            merge_root_panic(
                                &mut root,
                                format!("rank {rank} sent a malformed reply: {e}"),
                            );
                        }
                    }
                    continue;
                }
                self.check_death(rank);
                if nread == 0 {
                    if let Some(msg) = &self.dead_msgs[rank] {
                        // reply ring fully drained and the worker is
                        // gone: it died without replying. Close its
                        // outgoing data rings so peers blocked on it
                        // cascade out instead of spinning forever.
                        done[rank] = true;
                        progressed = true;
                        merge_root_panic(&mut root, msg.clone());
                        self.shm
                            .close_outgoing(u32::try_from(rank).expect("rank fits u32"));
                    }
                }
            }
            if done.iter().all(|d| *d) {
                break;
            }
            if let Some(ms) = self.watchdog_timeout_ms {
                // WallStopwatch only accumulates across stop(): tick it
                sw.stop();
                sw.start();
                if sw.ns() / 1_000_000 >= ms {
                    let stuck: Vec<String> = done
                        .iter()
                        .enumerate()
                        .filter(|(_, d)| !**d)
                        .map(|(r, _)| format!("rank {r}"))
                        .collect();
                    merge_root_panic(
                        &mut root,
                        format!("watchdog: no reply within {ms} ms from {}", stuck.join(", ")),
                    );
                    break;
                }
            }
            if progressed {
                backoff.reset();
            } else {
                backoff.snooze();
            }
        }
        match root {
            None => Ok(out),
            Some(msg) => {
                self.poisoned = Some(msg.clone());
                Err(format!("virtual cluster poisoned: {msg}"))
            }
        }
    }

    /// Kill and reap every worker, reset the rings (fault cells
    /// survive), and fork a fresh generation from the parent-side
    /// state. The session layer restores dynamic state from its last
    /// auto-checkpoint afterwards.
    pub fn recover(&mut self) {
        for pid in &mut self.pids {
            if *pid != 0 {
                shm::kill_worker(*pid);
                shm::wait_reap(*pid);
                *pid = 0;
            }
        }
        self.shm.reset_rings();
        self.fork_all();
        self.poisoned = None;
    }
}

impl Drop for ProcPool {
    /// Unconditional SIGKILL + reap: worker processes idle in their
    /// command loops and carry nothing worth flushing (all durable
    /// state lives in checkpoints on the coordinator side).
    fn drop(&mut self) {
        for pid in &mut self.pids {
            if *pid != 0 {
                shm::kill_worker(*pid);
                shm::wait_reap(*pid);
                *pid = 0;
            }
        }
    }
}

/// Render a `waitpid` status into the root-cause message. Neither form
/// contains "hung up", so a real death always overrides cascade panics
/// in [`merge_root_panic`].
fn death_message(rank: usize, status: i32) -> String {
    let sig = status & 0x7f;
    if sig != 0 {
        format!("rank {rank} worker process killed by signal {sig}")
    } else {
        format!("rank {rank} worker process died (exit status {})", (status >> 8) & 0xff)
    }
}

/// The forked worker's main loop: the process-backed sibling of the
/// thread pool's `worker`. Never returns — every exit path goes
/// through `exit_now` (a forked child must not unwind into the
/// parent's stack frames or run the parent's destructors).
///
/// Exit codes: 0 clean (closed command ring / `Shutdown`), 101 injected
/// hard death (`FaultMode::Die` — no hang-up, no reply: the parent
/// must prove it can diagnose silence), 102 after a panic reply, 103
/// malformed command frame (protocol bug).
fn worker_process(rank: u32, proc: &mut RankProcess, shm: &ShmCluster, init_stats: CommStats) -> ! {
    // the coordinator reports panics from the reply frame; the default
    // hook would interleave every child's backtrace on shared stderr
    std::panic::set_hook(Box::new(|_| {}));
    proc.set_faults_fired(shm.fault_fired(rank));
    let mut comm =
        RankComm::from_transport_with_stats(Box::new(shm.transport(rank)), init_stats);
    let cmd_ring = shm.cmd_ring(rank);
    let reply_ring = shm.reply_ring(rank);
    let mut acc = FrameAcc::new();
    loop {
        // blocking read of the next command frame
        let frame = {
            let mut backoff = Backoff::new();
            loop {
                let (n, frame) = acc.poll(&cmd_ring);
                if let Some(f) = frame {
                    break f;
                }
                if n > 0 {
                    backoff.reset();
                    continue;
                }
                if cmd_ring.is_closed() && !acc.mid_frame() {
                    shm::exit_now(0);
                }
                backoff.snooze();
            }
        };
        let cmd = match codec::decode_command(&frame) {
            Ok(cmd) => cmd,
            Err(_) => shm::exit_now(103),
        };
        let shutdown = matches!(cmd, Command::Shutdown);
        let result =
            catch_unwind(AssertUnwindSafe(|| execute_command(cmd, rank, &mut *proc, &mut comm)));
        match result {
            Ok(out) => {
                // publish the fault-fire count after EVERY command so a
                // later re-fork (recovery) seeds the spent budget
                shm.set_fault_fired(rank, proc.faults_fired());
                if shutdown {
                    shm::exit_now(0);
                }
                match out.reply_fault {
                    Some(FaultMode::Hang) => loop {
                        // never reply, never exit: the watchdog must
                        // diagnose this rank by its silence
                        std::thread::sleep(Duration::from_secs(3600));
                    },
                    Some(FaultMode::DelayReplyMs(ms)) => {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    Some(FaultMode::Panic | FaultMode::Die) | None => {}
                }
                shm::write_frame(&reply_ring, &codec::encode_done(rank, &out));
            }
            Err(payload) => {
                shm.set_fault_fired(rank, proc.faults_fired());
                let msg = panic_message(&*payload);
                if msg.contains(DIE_MARKER) {
                    // hard death: no hang-up, no reply — the parent
                    // must diagnose this through waitpid alone
                    shm::exit_now(101);
                }
                // close outgoing rings FIRST so peers blocked on this
                // rank cascade instead of deadlocking, then report
                comm.hang_up();
                shm::write_frame(&reply_ring, &codec::encode_panicked(rank, &msg));
                shm::exit_now(102);
            }
        }
    }
}

/// Frame payload codecs for the command/reply protocol, over the
/// checkpoint wire primitives (little-endian, like everything else
/// that crosses a rank boundary here).
mod codec {
    use crate::checkpoint::codec::{CheckpointError, Reader, Writer};
    use crate::checkpoint::RankState;
    use crate::config::ExternalParams;
    use crate::engine::metrics::PHASES;

    use super::super::executor::{CmdOutcome, Command, ObserveFrame, Reply};

    pub(super) fn encode_command(cmd: &Command) -> Vec<u8> {
        let mut w = Writer::new();
        match cmd {
            Command::Run { step0, steps, observe } => {
                w.put_u8(0);
                w.put_u64(*step0);
                w.put_u64(*steps);
                w.put_u8(u8::from(*observe));
            }
            Command::Probe => w.put_u8(1),
            Command::Reset => w.put_u8(2),
            Command::SetExternal { area, external } => {
                w.put_u8(3);
                w.put_u8(u8::from(area.is_some()));
                w.put_u32(area.unwrap_or(0));
                w.put_u32(external.synapses_per_neuron);
                w.put_f64(external.rate_hz);
            }
            Command::Snapshot => w.put_u8(4),
            Command::Restore { state, rebase_delta } => {
                w.put_u8(5);
                w.put_u64(*rebase_delta);
                state.encode_into(&mut w);
            }
            Command::Shutdown => w.put_u8(6),
            Command::Report => w.put_u8(7),
        }
        w.into_bytes()
    }

    pub(super) fn decode_command(bytes: &[u8]) -> Result<Command, CheckpointError> {
        let mut r = Reader::new(bytes);
        let cmd = match r.take_u8()? {
            0 => Command::Run {
                step0: r.take_u64()?,
                steps: r.take_u64()?,
                observe: r.take_u8()? != 0,
            },
            1 => Command::Probe,
            2 => Command::Reset,
            3 => {
                let has_area = r.take_u8()? != 0;
                let area_idx = r.take_u32()?;
                let external = ExternalParams {
                    synapses_per_neuron: r.take_u32()?,
                    rate_hz: r.take_f64()?,
                };
                Command::SetExternal { area: has_area.then_some(area_idx), external }
            }
            4 => Command::Snapshot,
            5 => {
                let rebase_delta = r.take_u64()?;
                let state = Box::new(RankState::decode_from(&mut r)?);
                Command::Restore { state, rebase_delta }
            }
            6 => Command::Shutdown,
            7 => Command::Report,
            t => {
                return Err(CheckpointError::Malformed(format!("unknown command tag {t}")));
            }
        };
        r.expect_end()?;
        Ok(cmd)
    }

    fn put_frame(w: &mut Writer, f: &ObserveFrame) {
        w.put_u32(u32::try_from(f.col_spikes.len()).expect("column count fits u32"));
        for &c in &f.col_spikes {
            w.put_u32(c);
        }
        for &ns in &f.phase_ns {
            w.put_u64(ns);
        }
    }

    fn take_frame(r: &mut Reader<'_>) -> Result<ObserveFrame, CheckpointError> {
        let n = r.take_u32()?;
        let mut col_spikes = Vec::with_capacity(n as usize);
        for _ in 0..n {
            col_spikes.push(r.take_u32()?);
        }
        let mut phase_ns = [0u64; PHASES.len()];
        for slot in &mut phase_ns {
            *slot = r.take_u64()?;
        }
        Ok(ObserveFrame { col_spikes, phase_ns })
    }

    pub(super) fn encode_done(rank: u32, out: &CmdOutcome) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u8(0);
        w.put_u32(rank);
        w.put_u32(u32::try_from(out.frames.len()).expect("frame count fits u32"));
        for f in &out.frames {
            put_frame(&mut w, f);
        }
        match &out.state {
            None => w.put_u8(0),
            Some(s) => {
                w.put_u8(1);
                s.encode_into(&mut w);
            }
        }
        match &out.report {
            None => w.put_u8(0),
            Some(v) => {
                w.put_u8(1);
                w.put_u32(u32::try_from(v.len()).expect("report length fits u32"));
                for &x in v {
                    w.put_u64(x);
                }
            }
        }
        w.into_bytes()
    }

    pub(super) fn encode_panicked(rank: u32, msg: &str) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u8(1);
        w.put_u32(rank);
        w.put_u32(u32::try_from(msg.len()).expect("panic message fits u32"));
        w.put_bytes(msg.as_bytes());
        w.into_bytes()
    }

    pub(super) fn decode_reply(bytes: &[u8]) -> Result<Reply, CheckpointError> {
        let mut r = Reader::new(bytes);
        let reply = match r.take_u8()? {
            0 => {
                let rank = r.take_u32()?;
                let n_frames = r.take_u32()?;
                let mut frames = Vec::with_capacity(n_frames as usize);
                for _ in 0..n_frames {
                    frames.push(take_frame(&mut r)?);
                }
                let state = if r.take_u8()? != 0 {
                    Some(Box::new(RankState::decode_from(&mut r)?))
                } else {
                    None
                };
                let report = if r.take_u8()? != 0 {
                    let len = r.take_u32()?;
                    let mut v = Vec::with_capacity(len as usize);
                    for _ in 0..len {
                        v.push(r.take_u64()?);
                    }
                    Some(v)
                } else {
                    None
                };
                Reply::Done { rank, frames, state, report }
            }
            1 => {
                let rank = r.take_u32()?;
                let len = r.take_u32()?;
                let msg = String::from_utf8_lossy(r.take_bytes(len as usize)?).into_owned();
                Reply::Panicked { rank, msg }
            }
            t => {
                return Err(CheckpointError::Malformed(format!("unknown reply tag {t}")));
            }
        };
        r.expect_end()?;
        Ok(reply)
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn command_frames_roundtrip() {
            let cases = [
                Command::Run { step0: 7, steps: 40, observe: true },
                Command::Probe,
                Command::Reset,
                Command::SetExternal {
                    area: Some(3),
                    external: ExternalParams { synapses_per_neuron: 10, rate_hz: 2.5 },
                },
                Command::SetExternal {
                    area: None,
                    external: ExternalParams { synapses_per_neuron: 420, rate_hz: 3.0 },
                },
                Command::Snapshot,
                Command::Shutdown,
                Command::Report,
            ];
            for cmd in cases {
                let bytes = encode_command(&cmd);
                let back = decode_command(&bytes).expect("roundtrip decodes");
                assert_eq!(format!("{cmd:?}"), format!("{back:?}"));
            }
        }

        #[test]
        fn reply_frames_roundtrip() {
            let out = CmdOutcome {
                frames: vec![
                    ObserveFrame { col_spikes: vec![1, 0, 4], phase_ns: [9; PHASES.len()] },
                    ObserveFrame { col_spikes: vec![2, 2, 2], phase_ns: [1; PHASES.len()] },
                ],
                state: None,
                report: Some(vec![5, 6, 7]),
                reply_fault: None,
            };
            let bytes = encode_done(3, &out);
            match decode_reply(&bytes).expect("decodes") {
                Reply::Done { rank, frames, state, report } => {
                    assert_eq!(rank, 3);
                    assert_eq!(frames.len(), 2);
                    assert_eq!(frames[0].col_spikes, vec![1, 0, 4]);
                    assert_eq!(frames[1].phase_ns, [1; PHASES.len()]);
                    assert!(state.is_none());
                    assert_eq!(report, Some(vec![5, 6, 7]));
                }
                Reply::Panicked { .. } => panic!("wrong reply variant"),
            }

            let bytes = encode_panicked(1, "rank 1 panicked: boom");
            match decode_reply(&bytes).expect("decodes") {
                Reply::Panicked { rank, msg } => {
                    assert_eq!(rank, 1);
                    assert_eq!(msg, "rank 1 panicked: boom");
                }
                Reply::Done { .. } => panic!("wrong reply variant"),
            }
        }

        #[test]
        fn malformed_frames_error_instead_of_panicking() {
            assert!(decode_command(&[99]).is_err());
            assert!(decode_reply(&[42]).is_err());
            assert!(decode_command(&[]).is_err());
            // trailing garbage is a protocol error, not silently ignored
            let mut bytes = encode_command(&Command::Probe);
            bytes.push(0);
            assert!(decode_command(&bytes).is_err());
        }
    }
}
