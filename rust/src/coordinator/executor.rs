//! Persistent rank executor: one long-lived OS thread per rank.
//!
//! The paper's DPSNN is a set of *long-lived* MPI processes that pace
//! each other once per time-driven step (§II-E). Earlier versions of
//! this engine approximated that with a thread team spawned per
//! `advance()` call — and per *step* when probes were attached — which
//! polluted exactly the per-phase timings the bench harness records.
//! The executor removes the churn: `Network::build` constructs the
//! per-rank state once, hands each `(RankProcess, RankComm)` pair to a
//! worker thread, and every subsequent `step()`/`advance()`/`reset()`
//! is a typed command on a per-rank channel:
//!
//! ```text
//!             ┌────────────────────────────────────────────┐
//!             │ Network (coordinator thread)               │
//!             │   cmd_tx[r]: Run{step0,steps,observe}      │
//!             │              Probe | Reset | Shutdown      │
//!             └──────┬──────────────┬──────────────┬───────┘
//!                    ▼              ▼              ▼
//!              worker rank0   worker rank1   worker rankR-1   (threads
//!              loop{recv cmd; lock slot; dispatch; reply}     live until
//!                    │              │              │           Shutdown
//!                    └── virtual-MPI collectives ──┘           or Drop)
//!                                   │
//!                    reply_rx: Done{frames} | Panicked{msg}
//! ```
//!
//! Shared state: each rank's `(RankProcess, RankComm)` lives in an
//! `Arc<Mutex<RankSlot>>`. A worker locks its slot only while executing
//! a command; the coordinator locks slots only *between* commands
//! (every dispatch waits for all replies before returning), so the
//! locks never contend — they exist to let `summary()`/`synapses()`/
//! `set_external()` read rank state without a serialization protocol.
//!
//! ## Panic propagation
//!
//! A panic inside a rank (construction bugs, injected faults) unwinds
//! into the worker's `catch_unwind`, which [`RankComm::hang_up`]s the
//! rank's outgoing channels before reporting `Panicked`. Peers blocked
//! mid-collective on the dead rank wake with "sender rank hung up",
//! panic in turn, and cascade — every worker reports exactly once, so
//! the coordinator never deadlocks collecting replies. The executor
//! then refuses all further commands with the *root* panic payload
//! (cascade panics are recognized and not allowed to mask it): the
//! session is poisoned, not wedged.
//!
//! ## Phase timings
//!
//! Workers time nothing themselves: `RankProcess::step` starts/stops
//! the per-phase CPU stopwatches exactly as before, on the worker
//! thread, so command dispatch and idle blocking never pollute the
//! recorded Pack/Exchange/Demux/Dynamics costs (`CLOCK_THREAD_CPUTIME`
//! does not advance while a worker waits on its command channel).
//! `BENCH.json`'s `executor_spawn_vs_pool` record quantifies the
//! spawn-churn win itself.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::ExternalParams;
use crate::engine::metrics::PHASES;
use crate::engine::process::RankProcess;
use crate::engine::RankReport;
use crate::mpi::{panic_message, RankComm};

/// One rank's persistent state: the simulation process plus its
/// communicator, created at build time and reused for every command.
pub(crate) struct RankSlot {
    pub proc: RankProcess,
    pub comm: RankComm,
}

/// Commands the coordinator sends to a rank worker.
#[derive(Clone, Copy, Debug)]
enum Command {
    /// Drive `steps` time-driven steps starting at `step0`, with
    /// per-step column-spike observation on or off. The reply carries
    /// **one [`ObserveFrame`] per step** when `observe` is set: probed
    /// advances batch K steps per command and the frames ride back as a
    /// `Vec`, so observation costs one dispatch per batch instead of
    /// one per step.
    Run { step0: u64, steps: u64, observe: bool },
    /// Report the current observation frame without stepping (probe
    /// baselines).
    Probe,
    /// Rewind dynamics to t = 0 and restart the comm statistics.
    Reset,
    /// Swap the external Poisson drive from the next step boundary:
    /// the global drive (`area: None`, re-resolving every per-area
    /// override against it) or one area's drive (`area: Some(i)`,
    /// reseeding only that area's stimulus calendar). Typed like
    /// `Run`/`Reset` so sweeps ride the same dispatch/reply protocol.
    SetExternal { area: Option<u32>, external: ExternalParams },
    /// Exit the worker thread.
    Shutdown,
}

/// Per-rank observation snapshot riding back on a reply: one step's
/// per-column spike counts and the cumulative per-phase CPU totals at
/// the end of that step (the session layer turns consecutive totals
/// into per-step deltas for `PhaseMetricsProbe`).
#[derive(Clone, Debug, Default)]
pub(crate) struct ObserveFrame {
    pub col_spikes: Vec<u32>,
    pub phase_ns: [u64; PHASES.len()],
}

enum Reply {
    Done { rank: u32, frames: Vec<ObserveFrame> },
    Panicked { rank: u32, msg: String },
}

/// The worker pool. Owned by `Network`; dropped ⇒ workers shut down.
pub(crate) struct Executor {
    slots: Vec<Arc<Mutex<RankSlot>>>,
    cmd_tx: Vec<Sender<Command>>,
    reply_rx: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
    /// Root panic message once any rank died; all further commands are
    /// refused with it.
    poisoned: Option<String>,
}

impl Executor {
    /// Spawn one persistent worker per rank, seeded with the
    /// already-constructed rank state.
    pub fn launch(pairs: Vec<(RankProcess, RankComm)>) -> Executor {
        let slots: Vec<Arc<Mutex<RankSlot>>> = pairs
            .into_iter()
            .map(|(proc, comm)| Arc::new(Mutex::new(RankSlot { proc, comm })))
            .collect();
        let (reply_tx, reply_rx) = channel();
        let mut cmd_tx = Vec::with_capacity(slots.len());
        let mut handles = Vec::with_capacity(slots.len());
        for (rank, slot) in slots.iter().enumerate() {
            let (tx, rx) = channel();
            cmd_tx.push(tx);
            let slot = Arc::clone(slot);
            let reply_tx = reply_tx.clone();
            let h = std::thread::Builder::new()
                .name(format!("rank{rank}"))
                .stack_size(8 << 20)
                .spawn(move || worker(rank as u32, &slot, &rx, &reply_tx))
                .expect("spawn rank worker thread");
            handles.push(h);
        }
        // workers hold the only reply senders: reply_rx disconnects iff
        // every worker exited, which collect() treats as poisoning
        drop(reply_tx);
        Executor { slots, cmd_tx, reply_rx, handles, poisoned: None }
    }

    /// The root panic message, if any rank has died.
    pub fn poison_message(&self) -> Option<&str> {
        self.poisoned.as_deref()
    }

    /// Drive every rank through `steps` steps starting at `step0`.
    /// When `observe` is set, returns one frame **per rank per step**
    /// (`result[rank][k]` observes step `step0 + k`): one command
    /// covers a whole probed batch, with the frames riding back as a
    /// `Vec`. Unobserved runs return empty per-rank vectors.
    pub fn run(
        &mut self,
        step0: u64,
        steps: u64,
        observe: bool,
    ) -> Result<Vec<Vec<ObserveFrame>>, String> {
        self.dispatch(Command::Run { step0, steps, observe })
    }

    /// Snapshot every rank's observation frame without stepping.
    pub fn probe(&mut self) -> Result<Vec<ObserveFrame>, String> {
        let per_rank = self.dispatch(Command::Probe)?;
        Ok(per_rank
            .into_iter()
            .map(|mut frames| {
                debug_assert_eq!(frames.len(), 1);
                frames.pop().unwrap_or_default()
            })
            .collect())
    }

    /// Rewind every rank's dynamics to t = 0 (in parallel) and restart
    /// the per-rank comm statistics.
    pub fn reset(&mut self) -> Result<(), String> {
        self.dispatch(Command::Reset).map(|_| ())
    }

    /// Swap the external drive on every rank: the global drive
    /// (`area: None`) or one atlas area's (`area: Some(i)`, a mid-run
    /// per-area sweep). The caller guarantees `i` is a valid atlas
    /// area index.
    pub fn set_external(
        &mut self,
        area: Option<u32>,
        external: ExternalParams,
    ) -> Result<(), String> {
        self.dispatch(Command::SetExternal { area, external }).map(|_| ())
    }

    /// Run `f` over every rank slot (coordinator-side access between
    /// commands: summaries, stimulus swaps, static topology reads).
    /// Recovers poisoned slot locks — after a rank panic the state is
    /// still readable for reporting.
    pub fn with_slots<R>(&self, mut f: impl FnMut(&mut RankSlot) -> R) -> Vec<R> {
        self.slots
            .iter()
            .map(|slot| {
                let mut guard = slot.lock().unwrap_or_else(|p| p.into_inner());
                f(&mut guard)
            })
            .collect()
    }

    /// Per-rank reports with comm statistics folded in.
    pub fn reports(&self) -> Vec<RankReport> {
        self.with_slots(|slot| {
            let RankSlot { proc, comm } = slot;
            proc.report(comm.stats())
        })
    }

    fn dispatch(&mut self, cmd: Command) -> Result<Vec<Vec<ObserveFrame>>, String> {
        if let Some(msg) = &self.poisoned {
            return Err(format!("virtual cluster poisoned: {msg}"));
        }
        for tx in &self.cmd_tx {
            if tx.send(cmd).is_err() {
                // only reachable if a worker died outside a command —
                // poison defensively rather than hang on collect
                self.poisoned = Some("rank worker exited unexpectedly".to_string());
                return Err("virtual cluster poisoned: rank worker exited unexpectedly"
                    .to_string());
            }
        }
        self.collect()
    }

    /// Wait for exactly one reply per rank. Every worker replies once
    /// per command — panicking workers hang up their channels first, so
    /// peers blocked on them cascade-panic and still reply (see the
    /// module docs) — hence this never deadlocks.
    fn collect(&mut self) -> Result<Vec<Vec<ObserveFrame>>, String> {
        let n = self.slots.len();
        let mut frames = vec![Vec::new(); n];
        let mut root_panic: Option<String> = None;
        for _ in 0..n {
            match self.reply_rx.recv() {
                Ok(Reply::Done { rank, frames: f }) => {
                    frames[rank as usize] = f;
                }
                Ok(Reply::Panicked { rank, msg }) => {
                    let cascade = msg.contains("hung up");
                    let full = format!("rank {rank} panicked: {msg}");
                    match &mut root_panic {
                        None => root_panic = Some(full),
                        // a cascade panic must not mask the root cause
                        Some(cur) if cur.contains("hung up") && !cascade => *cur = full,
                        Some(_) => {}
                    }
                }
                Err(_) => {
                    root_panic
                        .get_or_insert_with(|| "rank workers terminated unexpectedly".into());
                    break;
                }
            }
        }
        match root_panic {
            None => Ok(frames),
            Some(msg) => {
                self.poisoned = Some(msg.clone());
                Err(format!("virtual cluster poisoned: {msg}"))
            }
        }
    }
}

impl Drop for Executor {
    /// Dropping the executor (Network drop, with or without an explicit
    /// shutdown) terminates the pool cleanly: idle workers get
    /// `Shutdown`, dead workers' channels error harmlessly, and every
    /// thread is joined.
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Command::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The rank worker main loop: the paper's "simulation phase" process,
/// idling between commands. Every command executes under
/// `catch_unwind`; success replies `Done`, a panic hangs up the rank's
/// channels (unblocking peers) and replies `Panicked` with the payload.
fn worker(
    rank: u32,
    slot: &Arc<Mutex<RankSlot>>,
    cmd_rx: &Receiver<Command>,
    reply_tx: &Sender<Reply>,
) {
    loop {
        let cmd = match cmd_rx.recv() {
            Ok(cmd) => cmd,
            // coordinator gone (executor dropped mid-teardown)
            Err(_) => return,
        };
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut guard = slot.lock().expect("rank slot poisoned");
            let RankSlot { proc, comm } = &mut *guard;
            match cmd {
                Command::Shutdown => Vec::new(),
                Command::Run { step0, steps, observe } => {
                    proc.set_observe(observe);
                    let mut frames =
                        Vec::with_capacity(if observe { steps as usize } else { 0 });
                    for k in 0..steps {
                        proc.step(comm, step0 + k);
                        if observe {
                            frames.push(frame_of(proc));
                        }
                    }
                    frames
                }
                Command::Probe => vec![frame_of(proc)],
                Command::Reset => {
                    proc.reset();
                    let _ = comm.take_stats();
                    Vec::new()
                }
                Command::SetExternal { area, external } => {
                    match area {
                        None => proc.set_external(external),
                        Some(i) => proc.set_area_external(i as usize, external),
                    }
                    Vec::new()
                }
            }
        }));
        match result {
            Ok(frames) => {
                if matches!(cmd, Command::Shutdown) {
                    return;
                }
                if reply_tx.send(Reply::Done { rank, frames }).is_err() {
                    return;
                }
            }
            Err(payload) => {
                let msg = panic_message(&*payload);
                // disconnect our outgoing channels FIRST so any peer
                // blocked on this rank fails over instead of deadlocking
                let mut guard = slot.lock().unwrap_or_else(|p| p.into_inner());
                guard.comm.hang_up();
                drop(guard);
                let _ = reply_tx.send(Reply::Panicked { rank, msg });
                return;
            }
        }
    }
}

fn frame_of(proc: &RankProcess) -> ObserveFrame {
    let mut phase_ns = [0u64; PHASES.len()];
    for p in PHASES {
        phase_ns[p.index()] = proc.metrics.phase_ns(p);
    }
    ObserveFrame { col_spikes: proc.step_col_spikes().to_vec(), phase_ns }
}
