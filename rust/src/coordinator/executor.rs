//! Persistent rank executor: the coordinator-facing command fabric.
//!
//! The paper's DPSNN is a set of *long-lived* MPI processes that pace
//! each other once per time-driven step (§II-E). Earlier versions of
//! this engine approximated that with a thread team spawned per
//! `advance()` call — and per *step* when probes were attached — which
//! polluted exactly the per-phase timings the bench harness records.
//! The executor removes the churn: `Network::build` constructs the
//! per-rank state once, hands each `(RankProcess, RankComm)` pair to a
//! worker, and every subsequent `step()`/`advance()`/`reset()` is a
//! typed command on a per-rank channel.
//!
//! Since the transport became pluggable (see `mpi::comm::Transport`)
//! the executor is a facade over two pools sharing one command
//! dispatcher ([`execute_command`]):
//!
//! * [`ThreadPool`] — ranks as threads, commands on mpsc channels,
//!   collectives over the in-process channel matrix. The reference
//!   backend, and the default.
//! * [`ProcPool`](super::procpool::ProcPool) — ranks as forked worker
//!   *processes*, commands as length-prefixed frames on mmap'd
//!   shared-memory rings, collectives over `mpi::shm` data rings
//!   (`--transport shm`).
//!
//! ```text
//!             ┌────────────────────────────────────────────┐
//!             │ Network (coordinator thread)               │
//!             │   cmd[r]: Run{step0,steps,observe}         │
//!             │           Probe | Reset | Snapshot         │
//!             │           Restore{state} | Report          │
//!             └──────┬──────────────┬──────────────┬───────┘
//!                    ▼              ▼              ▼
//!              worker rank0   worker rank1   worker rankR-1  (threads or
//!              loop{recv cmd; execute_command; reply}         processes)
//!                    │              │              │
//!                    └── virtual-MPI collectives ──┘
//!                                   │
//!                 reply: Done{frames,state,report} | Panicked{msg}
//! ```
//!
//! Thread-pool shared state: each rank's `(RankProcess, RankComm)`
//! lives in an `Arc<Mutex<RankSlot>>`. A worker locks its slot only
//! while executing a command; the coordinator locks slots only
//! *between* commands (every dispatch waits for all replies before
//! returning), so the locks never contend — they exist to let
//! `summary()`/`synapses()` read rank state without a serialization
//! protocol. The process pool has no shared slots: the parent keeps
//! its pristine construction-time copy (fork gave each child its own)
//! and anything dynamic rides back on replies.
//!
//! ## Panic propagation
//!
//! A panic inside a rank (construction bugs, injected faults) unwinds
//! into the worker's `catch_unwind`, which [`RankComm::hang_up`]s the
//! rank's outgoing channels before reporting `Panicked`. Peers blocked
//! mid-collective on the dead rank wake with a "hung up" panic, panic
//! in turn, and cascade — every worker reports exactly once, so the
//! coordinator never deadlocks collecting replies. The executor then
//! refuses all further commands with the *root* panic payload (cascade
//! panics are recognized and not allowed to mask it): the session is
//! poisoned, not wedged.
//!
//! ## Watchdog and recovery
//!
//! Poisoning used to be terminal. Two escapes exist now (both driven by
//! `RunOptions`, see docs/RELIABILITY.md):
//!
//! * a **watchdog** deadline on collect: a rank that never replies (a
//!   hang or a silent worker death, not a panic) poisons the session
//!   with a message *naming the stuck rank* instead of blocking the
//!   coordinator forever. Stuck worker threads are detached, never
//!   joined; a dead worker *process* is additionally diagnosed through
//!   `waitpid` before any watchdog fires (see `procpool`).
//! * `recover` rebuilds the pool around the surviving simulation
//!   state: fresh communicators, fresh channels/rings, fresh workers.
//!   The session layer then replays from its last auto-checkpoint.
//!
//! ## Phase timings
//!
//! Workers time nothing themselves: `RankProcess::step` starts/stops
//! the per-phase CPU stopwatches exactly as before, on the worker
//! thread, so command dispatch and idle blocking never pollute the
//! recorded Pack/Exchange/Demux/Dynamics costs (`CLOCK_THREAD_CPUTIME`
//! does not advance while a worker waits on its command channel).
//! `BENCH.json`'s `executor_spawn_vs_pool` record quantifies the
//! spawn-churn win itself.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::checkpoint::{RankExpectation, RankState};
use crate::config::ExternalParams;
use crate::engine::metrics::PHASES;
use crate::engine::process::{FaultMode, RankProcess, DIE_MARKER};
use crate::engine::RankReport;
use crate::mpi::{panic_message, Cluster, RankComm};

use super::procpool::ProcPool;

/// One rank's persistent state: the simulation process plus its
/// communicator, created at build time and reused for every command.
pub(crate) struct RankSlot {
    pub proc: RankProcess,
    pub comm: RankComm,
}

/// Commands the coordinator sends to a rank worker. The process
/// backend serializes these onto command rings (`procpool::codec`);
/// the thread backend sends them as-is.
#[derive(Clone, Debug)]
pub(crate) enum Command {
    /// Drive `steps` time-driven steps starting at `step0`, with
    /// per-step column-spike observation on or off. The reply carries
    /// **one [`ObserveFrame`] per step** when `observe` is set: probed
    /// advances batch K steps per command and the frames ride back as a
    /// `Vec`, so observation costs one dispatch per batch instead of
    /// one per step.
    Run { step0: u64, steps: u64, observe: bool },
    /// Report the current observation frame without stepping (probe
    /// baselines).
    Probe,
    /// Rewind dynamics to t = 0 and restart the comm statistics.
    Reset,
    /// Swap the external Poisson drive from the next step boundary:
    /// the global drive (`area: None`, re-resolving every per-area
    /// override against it) or one area's drive (`area: Some(i)`,
    /// reseeding only that area's stimulus calendar). Typed like
    /// `Run`/`Reset` so sweeps ride the same dispatch/reply protocol.
    SetExternal { area: Option<u32>, external: ExternalParams },
    /// Capture the rank's dynamic state; it rides back on the reply
    /// (`checkpoint/` serializes the collected records).
    Snapshot,
    /// Overwrite the rank's dynamic state from a checkpoint record
    /// (shape-validated coordinator-side before dispatch, so the
    /// worker-side restore cannot fail), then optionally re-zero the
    /// time origin by `rebase_delta` dt-steps (`RankProcess::rebase`).
    Restore { state: Box<RankState>, rebase_delta: u64 },
    /// Ship the rank's metrics report back in `u64` wire form. The
    /// thread pool reads reports directly through its shared slots;
    /// the process pool has no shared memory view of a child's
    /// metrics, so reporting is a command like any other.
    Report,
    /// Exit the worker.
    Shutdown,
}

/// Per-rank observation snapshot riding back on a reply: one step's
/// per-column spike counts and the cumulative per-phase CPU totals at
/// the end of that step (the session layer turns consecutive totals
/// into per-step deltas for `PhaseMetricsProbe`).
#[derive(Clone, Debug, Default)]
pub(crate) struct ObserveFrame {
    pub col_spikes: Vec<u32>,
    pub phase_ns: [u64; PHASES.len()],
}

pub(crate) enum Reply {
    Done {
        rank: u32,
        frames: Vec<ObserveFrame>,
        state: Option<Box<RankState>>,
        report: Option<Vec<u64>>,
    },
    Panicked {
        rank: u32,
        msg: String,
    },
}

/// What one command produced on a worker, before the reply is sent.
/// Split out so reply-time faults act *after* the slot lock drops: a
/// hung worker must not wedge `summary()`/`with_procs` readers.
pub(crate) struct CmdOutcome {
    pub frames: Vec<ObserveFrame>,
    pub state: Option<Box<RankState>>,
    pub report: Option<Vec<u64>>,
    pub reply_fault: Option<FaultMode>,
}

/// One dispatch round's collected replies, indexed by rank.
pub(crate) struct CollectOut {
    pub frames: Vec<Vec<ObserveFrame>>,
    pub states: Vec<Option<Box<RankState>>>,
    pub reports: Vec<Option<Vec<u64>>>,
}

impl CollectOut {
    pub(crate) fn empty(n: usize) -> CollectOut {
        CollectOut {
            frames: vec![Vec::new(); n],
            states: (0..n).map(|_| None).collect(),
            reports: (0..n).map(|_| None).collect(),
        }
    }
}

/// Execute one command against one rank's state. This is THE dispatch
/// table, shared verbatim by the thread worker and the forked process
/// worker — backend bit-identity starts with both backends running
/// literally the same code here.
pub(crate) fn execute_command(
    cmd: Command,
    rank: u32,
    proc: &mut RankProcess,
    comm: &mut RankComm,
) -> CmdOutcome {
    let mut out =
        CmdOutcome { frames: Vec::new(), state: None, report: None, reply_fault: None };
    match cmd {
        Command::Shutdown => {}
        Command::Run { step0, steps, observe } => {
            proc.set_observe(observe);
            // capacity is a hint: a (theoretical) overflow of usize
            // just skips the preallocation
            let cap = if observe { usize::try_from(steps).unwrap_or(0) } else { 0 };
            let mut frames = Vec::with_capacity(cap);
            for k in 0..steps {
                proc.step(comm, step0 + k);
                if observe {
                    frames.push(frame_of(proc));
                }
            }
            out.frames = frames;
        }
        Command::Probe => out.frames = vec![frame_of(proc)],
        Command::Reset => {
            proc.reset();
            let _ = comm.take_stats();
        }
        Command::SetExternal { area, external } => match area {
            None => proc.set_external(external),
            Some(i) => proc.set_area_external(i as usize, external),
        },
        Command::Snapshot => {
            out.state = Some(Box::new(proc.snapshot_state()));
        }
        Command::Restore { state, rebase_delta } => {
            // validated coordinator-side; a mismatch reaching this far
            // is a protocol bug worth poisoning over
            if let Err(e) = proc.restore_state(&state) {
                panic!("restore failed on rank {rank}: {e}");
            }
            if rebase_delta > 0 {
                proc.rebase(rebase_delta);
            }
        }
        Command::Report => {
            out.report = Some(proc.report_wire(comm.stats()));
        }
    }
    // injected reply-time faults (Hang / DelayReply) are consumed here
    // but ACTED ON after the slot lock drops / before the reply frame,
    // so a hung worker never wedges coordinator-side readers
    out.reply_fault = proc.take_reply_fault();
    out
}

/// Merge a reply's panic message into the running root-cause slot.
/// Cascade panics ("hung up": a peer died first) and watchdog verdicts
/// must not mask a real root; a real root must overwrite a cascade
/// that happened to arrive earlier.
pub(crate) fn merge_root_panic(root: &mut Option<String>, msg: String) {
    let cascade = msg.contains("hung up");
    match root {
        None => *root = Some(msg),
        Some(cur) if cur.contains("hung up") && !cascade => *cur = msg,
        Some(_) => {}
    }
}

/// The executor: the worker pool behind `Network`, over one of the two
/// transport backends. Owned by `Network`; dropped ⇒ workers shut
/// down (threads joined, worker processes killed and reaped).
pub(crate) enum Executor {
    Threads(ThreadPool),
    Procs(ProcPool),
}

impl Executor {
    /// Spawn the reference backend: one persistent worker thread per
    /// rank, seeded with the already-constructed rank state.
    /// `watchdog_timeout_ms` bounds every per-rank command reply;
    /// `None` waits forever.
    pub fn launch(
        pairs: Vec<(RankProcess, RankComm)>,
        watchdog_timeout_ms: Option<u64>,
    ) -> Executor {
        Executor::Threads(ThreadPool::launch(pairs, watchdog_timeout_ms))
    }

    /// Fork the shared-memory backend: one worker *process* per rank.
    /// Construction already happened in this process (over the channel
    /// transport); each child inherits its rank's state through fork
    /// and re-homes its communicator onto the shm rings, carrying the
    /// construction-phase comm statistics over.
    pub fn launch_procs(
        pairs: Vec<(RankProcess, RankComm)>,
        watchdog_timeout_ms: Option<u64>,
    ) -> Executor {
        Executor::Procs(ProcPool::launch(pairs, watchdog_timeout_ms))
    }

    /// The root panic message, if any rank has died.
    pub fn poison_message(&self) -> Option<&str> {
        match self {
            Executor::Threads(p) => p.poisoned.as_deref(),
            Executor::Procs(p) => p.poison_message(),
        }
    }

    fn dispatch_each(
        &mut self,
        make: impl FnMut(usize) -> Command,
    ) -> Result<CollectOut, String> {
        match self {
            Executor::Threads(p) => p.dispatch_each(make),
            Executor::Procs(p) => p.dispatch_each(make),
        }
    }

    /// Drive every rank through `steps` steps starting at `step0`.
    /// When `observe` is set, returns one frame **per rank per step**
    /// (`result[rank][k]` observes step `step0 + k`): one command
    /// covers a whole probed batch, with the frames riding back as a
    /// `Vec`. Unobserved runs return empty per-rank vectors.
    pub fn run(
        &mut self,
        step0: u64,
        steps: u64,
        observe: bool,
    ) -> Result<Vec<Vec<ObserveFrame>>, String> {
        self.dispatch_each(|_| Command::Run { step0, steps, observe }).map(|o| o.frames)
    }

    /// Snapshot every rank's observation frame without stepping.
    pub fn probe(&mut self) -> Result<Vec<ObserveFrame>, String> {
        let out = self.dispatch_each(|_| Command::Probe)?;
        Ok(out
            .frames
            .into_iter()
            .map(|mut frames| {
                debug_assert_eq!(frames.len(), 1);
                frames.pop().unwrap_or_default()
            })
            .collect())
    }

    /// Rewind every rank's dynamics to t = 0 (in parallel) and restart
    /// the per-rank comm statistics.
    pub fn reset(&mut self) -> Result<(), String> {
        self.dispatch_each(|_| Command::Reset).map(|_| ())
    }

    /// Swap the external drive on every rank: the global drive
    /// (`area: None`) or one atlas area's (`area: Some(i)`, a mid-run
    /// per-area sweep). The caller guarantees `i` is a valid atlas
    /// area index.
    pub fn set_external(
        &mut self,
        area: Option<u32>,
        external: ExternalParams,
    ) -> Result<(), String> {
        self.dispatch_each(|_| Command::SetExternal { area, external }).map(|_| ())
    }

    /// Capture every rank's dynamic state, in parallel, ordered by
    /// rank (the building block of `Network::checkpoint`).
    pub fn snapshot(&mut self) -> Result<Vec<RankState>, String> {
        let out = self.dispatch_each(|_| Command::Snapshot)?;
        out.states
            .into_iter()
            .enumerate()
            .map(|(r, s)| {
                s.map(|b| *b).ok_or_else(|| format!("rank {r} returned no snapshot"))
            })
            .collect()
    }

    /// Overwrite every rank's dynamic state from checkpoint records
    /// (one per rank, in rank order), rebasing the time origin by
    /// `rebase_delta` dt-steps. The caller MUST have validated every
    /// record against [`Executor::expectations`] — a shape mismatch
    /// slipping through panics the worker and poisons the session.
    pub fn restore(
        &mut self,
        states: Vec<RankState>,
        rebase_delta: u64,
    ) -> Result<(), String> {
        assert_eq!(states.len(), self.ranks(), "one restore record per rank");
        let mut boxed: Vec<Option<Box<RankState>>> =
            states.into_iter().map(|s| Some(Box::new(s))).collect();
        self.dispatch_each(|r| Command::Restore {
            state: boxed[r].take().expect("restore record already dispatched"),
            rebase_delta,
        })
        .map(|_| ())
    }

    fn ranks(&self) -> usize {
        match self {
            Executor::Threads(p) => p.slots.len(),
            Executor::Procs(p) => p.ranks(),
        }
    }

    /// Per-rank shape signatures for coordinator-side checkpoint
    /// validation (see `RankState::validate`). Shapes are fixed at
    /// construction, so the process pool answers from its pristine
    /// parent-side copy without a round-trip.
    pub fn expectations(&self) -> Vec<RankExpectation> {
        self.with_procs(|proc| proc.expectation())
    }

    /// Rebuild the pool around the surviving simulation state after a
    /// poisoning: fresh communicators (the old ones have hung-up
    /// channels or rings), fresh workers. Hung worker threads are
    /// detached and dead worker processes reaped. The session layer
    /// restores simulation state from its last auto-checkpoint
    /// afterwards — which is what makes the two backends converge
    /// bit-identically even though the thread pool keeps the
    /// advanced (pre-fault) state and the process pool re-forks from
    /// the pristine construction state.
    pub fn recover(&mut self) {
        match self {
            Executor::Threads(p) => p.recover(),
            Executor::Procs(p) => p.recover(),
        }
    }

    /// Run `f` over every rank's *coordinator-visible* process state,
    /// in rank order. Threads: the live shared slots (between
    /// commands). Processes: the parent's construction-time copy —
    /// static topology (synapse counts, shapes) is exact; dynamic
    /// fields are whatever construction left (callers needing dynamic
    /// state use commands, not this).
    pub fn with_procs<R>(&self, f: impl FnMut(&RankProcess) -> R) -> Vec<R> {
        match self {
            Executor::Threads(p) => {
                let mut f = f;
                p.with_slots(|slot| f(&slot.proc))
            }
            Executor::Procs(p) => p.with_procs(f),
        }
    }

    /// Per-rank reports with comm statistics folded in. The thread
    /// pool reads its shared slots directly (works even poisoned); the
    /// process pool round-trips a `Report` command, degrading to the
    /// parent's construction-time view if the pool is poisoned.
    pub fn reports(&mut self) -> Vec<RankReport> {
        match self {
            Executor::Threads(p) => p.with_slots(|slot| {
                let RankSlot { proc, comm } = slot;
                proc.report(comm.stats())
            }),
            Executor::Procs(p) => p.reports(),
        }
    }
}

/// The reference backend: one long-lived OS thread per rank.
pub(crate) struct ThreadPool {
    slots: Vec<Arc<Mutex<RankSlot>>>,
    cmd_tx: Vec<Sender<Command>>,
    reply_rx: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
    /// Per-reply watchdog deadline [ms]; `None` blocks forever (the
    /// historical behavior).
    watchdog_timeout_ms: Option<u64>,
    /// Ranks whose worker never replied within the watchdog deadline.
    /// Their threads may be parked or wedged forever: teardown and
    /// recovery detach them instead of joining.
    hung: Vec<bool>,
    /// Root panic message once any rank died; all further commands are
    /// refused with it.
    poisoned: Option<String>,
}

impl ThreadPool {
    fn launch(
        pairs: Vec<(RankProcess, RankComm)>,
        watchdog_timeout_ms: Option<u64>,
    ) -> ThreadPool {
        let slots: Vec<Arc<Mutex<RankSlot>>> = pairs
            .into_iter()
            .map(|(proc, comm)| Arc::new(Mutex::new(RankSlot { proc, comm })))
            .collect();
        let n = slots.len();
        let (cmd_tx, reply_rx, handles) = spawn_workers(&slots);
        ThreadPool {
            slots,
            cmd_tx,
            reply_rx,
            handles,
            watchdog_timeout_ms,
            hung: vec![false; n],
            poisoned: None,
        }
    }

    fn recover(&mut self) {
        // closing the command channels errors every live worker's recv,
        // so each exits its loop; then join the joinable ones
        self.cmd_tx.clear();
        let hung = std::mem::replace(&mut self.hung, vec![false; self.slots.len()]);
        for (rank, h) in self.handles.drain(..).enumerate() {
            if hung.get(rank).copied().unwrap_or(false) {
                drop(h); // parked or wedged forever: detach
            } else {
                let _ = h.join();
            }
        }
        let ranks = u32::try_from(self.slots.len()).expect("rank count fits u32");
        let cluster = Cluster::new(ranks);
        for (rank, slot) in (0_u32..).zip(self.slots.iter()) {
            let mut guard = slot.lock().unwrap_or_else(PoisonError::into_inner);
            guard.comm = cluster.rank_comm(rank);
        }
        let (cmd_tx, reply_rx, handles) = spawn_workers(&self.slots);
        self.cmd_tx = cmd_tx;
        self.reply_rx = reply_rx;
        self.handles = handles;
        self.poisoned = None;
    }

    /// Run `f` over every rank slot (coordinator-side access between
    /// commands: summaries, stimulus swaps, static topology reads).
    /// Recovers poisoned slot locks — after a rank panic the state is
    /// still readable for reporting.
    fn with_slots<R>(&self, mut f: impl FnMut(&mut RankSlot) -> R) -> Vec<R> {
        self.slots
            .iter()
            .map(|slot| {
                let mut guard = slot.lock().unwrap_or_else(PoisonError::into_inner);
                f(&mut guard)
            })
            .collect()
    }

    /// Send one command per rank (`make(rank)`) and collect the
    /// replies.
    fn dispatch_each(
        &mut self,
        mut make: impl FnMut(usize) -> Command,
    ) -> Result<CollectOut, String> {
        if let Some(msg) = &self.poisoned {
            return Err(format!("virtual cluster poisoned: {msg}"));
        }
        for (rank, tx) in self.cmd_tx.iter().enumerate() {
            if tx.send(make(rank)).is_err() {
                // only reachable if a worker died outside a command —
                // poison defensively rather than hang on collect
                self.poisoned = Some("rank worker exited unexpectedly".to_string());
                return Err("virtual cluster poisoned: rank worker exited unexpectedly"
                    .to_string());
            }
        }
        self.collect()
    }

    /// Wait for exactly one reply per rank. Every worker replies once
    /// per command — panicking workers hang up their channels first, so
    /// peers blocked on them cascade-panic and still reply (see the
    /// module docs) — hence this deadlocks only if a worker *hangs* (or
    /// dies, `FaultMode::Die`) without panicking, which the watchdog
    /// deadline converts into a poisoning that names the stuck rank(s).
    fn collect(&mut self) -> Result<CollectOut, String> {
        let n = self.slots.len();
        let mut out = CollectOut::empty(n);
        let mut replied = vec![false; n];
        let mut root_panic: Option<String> = None;
        let deadline = self.watchdog_timeout_ms.map(Duration::from_millis);
        for _ in 0..n {
            let reply = match deadline {
                Some(d) => self.reply_rx.recv_timeout(d),
                None => self.reply_rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
            };
            match reply {
                Ok(Reply::Done { rank, frames, state, report }) => {
                    replied[rank as usize] = true;
                    out.frames[rank as usize] = frames;
                    out.states[rank as usize] = state;
                    out.reports[rank as usize] = report;
                }
                Ok(Reply::Panicked { rank, msg }) => {
                    replied[rank as usize] = true;
                    merge_root_panic(&mut root_panic, format!("rank {rank} panicked: {msg}"));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    root_panic
                        .get_or_insert_with(|| "rank workers terminated unexpectedly".into());
                    break;
                }
                Err(RecvTimeoutError::Timeout) => {
                    // name every rank still owing a reply and detach its
                    // worker: it may be parked forever. The verdict
                    // OVERWRITES a cascade-only root — when a worker
                    // died silently, its peers' "hung up" cascades
                    // arrive first and must not mask the diagnosis.
                    let mut stuck = Vec::new();
                    for (rank, done) in replied.iter().enumerate() {
                        if !done {
                            self.hung[rank] = true;
                            stuck.push(format!("rank {rank}"));
                        }
                    }
                    let ms = self.watchdog_timeout_ms.unwrap_or(0);
                    merge_root_panic(
                        &mut root_panic,
                        format!(
                            "watchdog: no reply within {ms} ms from {}",
                            stuck.join(", ")
                        ),
                    );
                    break;
                }
            }
        }
        match root_panic {
            None => Ok(out),
            Some(msg) => {
                self.poisoned = Some(msg.clone());
                Err(format!("virtual cluster poisoned: {msg}"))
            }
        }
    }
}

impl Drop for ThreadPool {
    /// Dropping the pool (Network drop, with or without an explicit
    /// shutdown) terminates it cleanly: idle workers get `Shutdown`,
    /// dead workers' channels error harmlessly, hung workers are
    /// detached, and every other thread is joined.
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Command::Shutdown);
        }
        for (rank, h) in self.handles.drain(..).enumerate() {
            if self.hung.get(rank).copied().unwrap_or(false) {
                drop(h); // watchdog victim: parked forever, never joins
            } else {
                let _ = h.join();
            }
        }
    }
}

/// Build the per-rank command channels, the shared reply channel, and
/// one worker thread per slot (used by both `launch` and `recover`).
/// Workers hold the only reply senders: `reply_rx` disconnects iff
/// every worker exited, which `collect` treats as poisoning.
fn spawn_workers(
    slots: &[Arc<Mutex<RankSlot>>],
) -> (Vec<Sender<Command>>, Receiver<Reply>, Vec<JoinHandle<()>>) {
    let (reply_tx, reply_rx) = channel();
    let mut cmd_tx = Vec::with_capacity(slots.len());
    let mut handles = Vec::with_capacity(slots.len());
    for (rank, slot) in (0_u32..).zip(slots.iter()) {
        let (tx, rx) = channel();
        cmd_tx.push(tx);
        let slot = Arc::clone(slot);
        let reply_tx = reply_tx.clone();
        let h = std::thread::Builder::new()
            .name(format!("rank{rank}"))
            .stack_size(8 << 20)
            .spawn(move || worker(rank, &slot, &rx, &reply_tx))
            .expect("spawn rank worker thread");
        handles.push(h);
    }
    drop(reply_tx);
    (cmd_tx, reply_rx, handles)
}

/// The rank worker main loop: the paper's "simulation phase" process,
/// idling between commands. Every command executes under
/// `catch_unwind`; success replies `Done`, a panic hangs up the rank's
/// channels (unblocking peers) and replies `Panicked` with the payload.
/// A recovered pool's worker may find its slot lock poisoned by its
/// predecessor — the state under it is a consistent pre-command
/// snapshot (the session replays over it), so the lock is recovered,
/// not propagated.
fn worker(
    rank: u32,
    slot: &Arc<Mutex<RankSlot>>,
    cmd_rx: &Receiver<Command>,
    reply_tx: &Sender<Reply>,
) {
    loop {
        let cmd = match cmd_rx.recv() {
            Ok(cmd) => cmd,
            // coordinator gone (executor dropped mid-teardown)
            Err(_) => return,
        };
        let shutdown = matches!(cmd, Command::Shutdown);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut guard = slot.lock().unwrap_or_else(PoisonError::into_inner);
            let RankSlot { proc, comm } = &mut *guard;
            execute_command(cmd, rank, proc, comm)
        }));
        match result {
            Ok(out) => {
                if shutdown {
                    return;
                }
                match out.reply_fault {
                    Some(FaultMode::Hang) => loop {
                        // never reply, never exit: the watchdog must
                        // diagnose this rank by its silence
                        std::thread::park();
                    },
                    Some(FaultMode::DelayReplyMs(ms)) => {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    Some(FaultMode::Panic | FaultMode::Die) | None => {}
                }
                let reply = Reply::Done {
                    rank,
                    frames: out.frames,
                    state: out.state,
                    report: out.report,
                };
                if reply_tx.send(reply).is_err() {
                    return;
                }
            }
            Err(payload) => {
                let msg = panic_message(&*payload);
                // disconnect our outgoing channels FIRST so any peer
                // blocked on this rank fails over instead of deadlocking
                let mut guard = slot.lock().unwrap_or_else(PoisonError::into_inner);
                guard.comm.hang_up();
                drop(guard);
                if msg.contains(DIE_MARKER) {
                    // a worker "death" on the thread backend: vanish
                    // without replying — peers cascade and the watchdog
                    // names this rank by its silence
                    return;
                }
                let _ = reply_tx.send(Reply::Panicked { rank, msg });
                return;
            }
        }
    }
}

pub(crate) fn frame_of(proc: &RankProcess) -> ObserveFrame {
    let mut phase_ns = [0u64; PHASES.len()];
    for p in PHASES {
        phase_ns[p.index()] = proc.metrics.phase_ns(p);
    }
    ObserveFrame { col_spikes: proc.step_col_spikes().to_vec(), phase_ns }
}
