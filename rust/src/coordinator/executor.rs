//! Persistent rank executor: one long-lived OS thread per rank.
//!
//! The paper's DPSNN is a set of *long-lived* MPI processes that pace
//! each other once per time-driven step (§II-E). Earlier versions of
//! this engine approximated that with a thread team spawned per
//! `advance()` call — and per *step* when probes were attached — which
//! polluted exactly the per-phase timings the bench harness records.
//! The executor removes the churn: `Network::build` constructs the
//! per-rank state once, hands each `(RankProcess, RankComm)` pair to a
//! worker thread, and every subsequent `step()`/`advance()`/`reset()`
//! is a typed command on a per-rank channel:
//!
//! ```text
//!             ┌────────────────────────────────────────────┐
//!             │ Network (coordinator thread)               │
//!             │   cmd_tx[r]: Run{step0,steps,observe}      │
//!             │              Probe | Reset | Snapshot      │
//!             │              Restore{state} | Shutdown     │
//!             └──────┬──────────────┬──────────────┬───────┘
//!                    ▼              ▼              ▼
//!              worker rank0   worker rank1   worker rankR-1   (threads
//!              loop{recv cmd; lock slot; dispatch; reply}     live until
//!                    │              │              │           Shutdown
//!                    └── virtual-MPI collectives ──┘           or Drop)
//!                                   │
//!                    reply_rx: Done{frames,state} | Panicked{msg}
//! ```
//!
//! Shared state: each rank's `(RankProcess, RankComm)` lives in an
//! `Arc<Mutex<RankSlot>>`. A worker locks its slot only while executing
//! a command; the coordinator locks slots only *between* commands
//! (every dispatch waits for all replies before returning), so the
//! locks never contend — they exist to let `summary()`/`synapses()`/
//! `set_external()` read rank state without a serialization protocol.
//!
//! ## Panic propagation
//!
//! A panic inside a rank (construction bugs, injected faults) unwinds
//! into the worker's `catch_unwind`, which [`RankComm::hang_up`]s the
//! rank's outgoing channels before reporting `Panicked`. Peers blocked
//! mid-collective on the dead rank wake with a "hung up" panic, panic
//! in turn, and cascade — every worker reports exactly once, so the
//! coordinator never deadlocks collecting replies. The executor then
//! refuses all further commands with the *root* panic payload (cascade
//! panics are recognized and not allowed to mask it): the session is
//! poisoned, not wedged.
//!
//! ## Watchdog and recovery
//!
//! Poisoning used to be terminal. Two escapes exist now (both driven by
//! `RunOptions`, see docs/RELIABILITY.md):
//!
//! * a **watchdog** deadline on [`Executor::collect`]: a rank that
//!   never replies (a hang, not a panic) poisons the session with a
//!   message *naming the stuck rank* instead of blocking the
//!   coordinator forever. Stuck workers are detached, never joined.
//! * [`Executor::recover`] rebuilds the pool around the surviving
//!   simulation state: fresh communicator matrix, fresh channels,
//!   fresh worker threads. The session layer then replays from its
//!   last auto-checkpoint.
//!
//! ## Phase timings
//!
//! Workers time nothing themselves: `RankProcess::step` starts/stops
//! the per-phase CPU stopwatches exactly as before, on the worker
//! thread, so command dispatch and idle blocking never pollute the
//! recorded Pack/Exchange/Demux/Dynamics costs (`CLOCK_THREAD_CPUTIME`
//! does not advance while a worker waits on its command channel).
//! `BENCH.json`'s `executor_spawn_vs_pool` record quantifies the
//! spawn-churn win itself.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::checkpoint::{RankExpectation, RankState};
use crate::config::ExternalParams;
use crate::engine::metrics::PHASES;
use crate::engine::process::{FaultMode, RankProcess};
use crate::engine::RankReport;
use crate::mpi::{panic_message, Cluster, RankComm};

/// One rank's persistent state: the simulation process plus its
/// communicator, created at build time and reused for every command.
pub(crate) struct RankSlot {
    pub proc: RankProcess,
    pub comm: RankComm,
}

/// Commands the coordinator sends to a rank worker.
#[derive(Clone, Debug)]
enum Command {
    /// Drive `steps` time-driven steps starting at `step0`, with
    /// per-step column-spike observation on or off. The reply carries
    /// **one [`ObserveFrame`] per step** when `observe` is set: probed
    /// advances batch K steps per command and the frames ride back as a
    /// `Vec`, so observation costs one dispatch per batch instead of
    /// one per step.
    Run { step0: u64, steps: u64, observe: bool },
    /// Report the current observation frame without stepping (probe
    /// baselines).
    Probe,
    /// Rewind dynamics to t = 0 and restart the comm statistics.
    Reset,
    /// Swap the external Poisson drive from the next step boundary:
    /// the global drive (`area: None`, re-resolving every per-area
    /// override against it) or one area's drive (`area: Some(i)`,
    /// reseeding only that area's stimulus calendar). Typed like
    /// `Run`/`Reset` so sweeps ride the same dispatch/reply protocol.
    SetExternal { area: Option<u32>, external: ExternalParams },
    /// Capture the rank's dynamic state; it rides back on the reply
    /// (`checkpoint/` serializes the collected records).
    Snapshot,
    /// Overwrite the rank's dynamic state from a checkpoint record
    /// (shape-validated coordinator-side before dispatch, so the
    /// worker-side restore cannot fail), then optionally re-zero the
    /// time origin by `rebase_delta` dt-steps (`RankProcess::rebase`).
    Restore { state: Box<RankState>, rebase_delta: u64 },
    /// Exit the worker thread.
    Shutdown,
}

/// Per-rank observation snapshot riding back on a reply: one step's
/// per-column spike counts and the cumulative per-phase CPU totals at
/// the end of that step (the session layer turns consecutive totals
/// into per-step deltas for `PhaseMetricsProbe`).
#[derive(Clone, Debug, Default)]
pub(crate) struct ObserveFrame {
    pub col_spikes: Vec<u32>,
    pub phase_ns: [u64; PHASES.len()],
}

enum Reply {
    Done { rank: u32, frames: Vec<ObserveFrame>, state: Option<Box<RankState>> },
    Panicked { rank: u32, msg: String },
}

/// What one command produced on a worker, before the reply is sent.
/// Split out so reply-time faults act *after* the slot lock drops: a
/// hung worker must not wedge `summary()`/`with_slots` readers.
struct CmdOutcome {
    frames: Vec<ObserveFrame>,
    state: Option<Box<RankState>>,
    reply_fault: Option<FaultMode>,
}

/// The worker pool. Owned by `Network`; dropped ⇒ workers shut down.
pub(crate) struct Executor {
    slots: Vec<Arc<Mutex<RankSlot>>>,
    cmd_tx: Vec<Sender<Command>>,
    reply_rx: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
    /// Per-reply watchdog deadline [ms]; `None` blocks forever (the
    /// historical behavior).
    watchdog_timeout_ms: Option<u64>,
    /// Ranks whose worker never replied within the watchdog deadline.
    /// Their threads may be parked or wedged forever: teardown and
    /// recovery detach them instead of joining.
    hung: Vec<bool>,
    /// Root panic message once any rank died; all further commands are
    /// refused with it.
    poisoned: Option<String>,
}

impl Executor {
    /// Spawn one persistent worker per rank, seeded with the
    /// already-constructed rank state. `watchdog_timeout_ms` bounds
    /// every per-rank command reply; `None` waits forever.
    pub fn launch(
        pairs: Vec<(RankProcess, RankComm)>,
        watchdog_timeout_ms: Option<u64>,
    ) -> Executor {
        let slots: Vec<Arc<Mutex<RankSlot>>> = pairs
            .into_iter()
            .map(|(proc, comm)| Arc::new(Mutex::new(RankSlot { proc, comm })))
            .collect();
        let n = slots.len();
        let (cmd_tx, reply_rx, handles) = spawn_workers(&slots);
        Executor {
            slots,
            cmd_tx,
            reply_rx,
            handles,
            watchdog_timeout_ms,
            hung: vec![false; n],
            poisoned: None,
        }
    }

    /// The root panic message, if any rank has died.
    pub fn poison_message(&self) -> Option<&str> {
        self.poisoned.as_deref()
    }

    /// Drive every rank through `steps` steps starting at `step0`.
    /// When `observe` is set, returns one frame **per rank per step**
    /// (`result[rank][k]` observes step `step0 + k`): one command
    /// covers a whole probed batch, with the frames riding back as a
    /// `Vec`. Unobserved runs return empty per-rank vectors.
    pub fn run(
        &mut self,
        step0: u64,
        steps: u64,
        observe: bool,
    ) -> Result<Vec<Vec<ObserveFrame>>, String> {
        self.dispatch_each(|_| Command::Run { step0, steps, observe }).map(|(f, _)| f)
    }

    /// Snapshot every rank's observation frame without stepping.
    pub fn probe(&mut self) -> Result<Vec<ObserveFrame>, String> {
        let (per_rank, _) = self.dispatch_each(|_| Command::Probe)?;
        Ok(per_rank
            .into_iter()
            .map(|mut frames| {
                debug_assert_eq!(frames.len(), 1);
                frames.pop().unwrap_or_default()
            })
            .collect())
    }

    /// Rewind every rank's dynamics to t = 0 (in parallel) and restart
    /// the per-rank comm statistics.
    pub fn reset(&mut self) -> Result<(), String> {
        self.dispatch_each(|_| Command::Reset).map(|_| ())
    }

    /// Swap the external drive on every rank: the global drive
    /// (`area: None`) or one atlas area's (`area: Some(i)`, a mid-run
    /// per-area sweep). The caller guarantees `i` is a valid atlas
    /// area index.
    pub fn set_external(
        &mut self,
        area: Option<u32>,
        external: ExternalParams,
    ) -> Result<(), String> {
        self.dispatch_each(|_| Command::SetExternal { area, external }).map(|_| ())
    }

    /// Capture every rank's dynamic state, in parallel, ordered by
    /// rank (the building block of `Network::checkpoint`).
    pub fn snapshot(&mut self) -> Result<Vec<RankState>, String> {
        let (_, states) = self.dispatch_each(|_| Command::Snapshot)?;
        states
            .into_iter()
            .enumerate()
            .map(|(r, s)| {
                s.map(|b| *b).ok_or_else(|| format!("rank {r} returned no snapshot"))
            })
            .collect()
    }

    /// Overwrite every rank's dynamic state from checkpoint records
    /// (one per rank, in rank order), rebasing the time origin by
    /// `rebase_delta` dt-steps. The caller MUST have validated every
    /// record against [`Executor::expectations`] — a shape mismatch
    /// slipping through panics the worker and poisons the session.
    pub fn restore(
        &mut self,
        states: Vec<RankState>,
        rebase_delta: u64,
    ) -> Result<(), String> {
        assert_eq!(states.len(), self.slots.len(), "one restore record per rank");
        let mut boxed: Vec<Option<Box<RankState>>> =
            states.into_iter().map(|s| Some(Box::new(s))).collect();
        self.dispatch_each(|r| Command::Restore {
            state: boxed[r].take().expect("restore record already dispatched"),
            rebase_delta,
        })
        .map(|_| ())
    }

    /// Per-rank shape signatures for coordinator-side checkpoint
    /// validation (see `RankState::validate`).
    pub fn expectations(&self) -> Vec<RankExpectation> {
        self.with_slots(|slot| slot.proc.expectation())
    }

    /// Rebuild the pool around the surviving simulation state after a
    /// poisoning: fresh communicator matrix (the old one has hung-up
    /// channels), fresh command/reply channels, fresh worker threads.
    /// Hung workers are detached; exited workers are joined. The
    /// `RankProcess` state in the slots is kept as-is — the session
    /// layer restores it from its last auto-checkpoint afterwards.
    pub fn recover(&mut self) {
        // closing the command channels errors every live worker's recv,
        // so each exits its loop; then join the joinable ones
        self.cmd_tx.clear();
        let hung = std::mem::replace(&mut self.hung, vec![false; self.slots.len()]);
        for (rank, h) in self.handles.drain(..).enumerate() {
            if hung.get(rank).copied().unwrap_or(false) {
                drop(h); // parked or wedged forever: detach
            } else {
                let _ = h.join();
            }
        }
        let ranks = u32::try_from(self.slots.len()).expect("rank count fits u32");
        let cluster = Cluster::new(ranks);
        for (rank, slot) in (0_u32..).zip(self.slots.iter()) {
            let mut guard = slot.lock().unwrap_or_else(PoisonError::into_inner);
            guard.comm = cluster.rank_comm(rank);
        }
        let (cmd_tx, reply_rx, handles) = spawn_workers(&self.slots);
        self.cmd_tx = cmd_tx;
        self.reply_rx = reply_rx;
        self.handles = handles;
        self.poisoned = None;
    }

    /// Run `f` over every rank slot (coordinator-side access between
    /// commands: summaries, stimulus swaps, static topology reads).
    /// Recovers poisoned slot locks — after a rank panic the state is
    /// still readable for reporting.
    pub fn with_slots<R>(&self, mut f: impl FnMut(&mut RankSlot) -> R) -> Vec<R> {
        self.slots
            .iter()
            .map(|slot| {
                let mut guard = slot.lock().unwrap_or_else(PoisonError::into_inner);
                f(&mut guard)
            })
            .collect()
    }

    /// Per-rank reports with comm statistics folded in.
    pub fn reports(&self) -> Vec<RankReport> {
        self.with_slots(|slot| {
            let RankSlot { proc, comm } = slot;
            proc.report(comm.stats())
        })
    }

    /// Send one command per rank (`make(rank)`) and collect the
    /// replies.
    fn dispatch_each(
        &mut self,
        mut make: impl FnMut(usize) -> Command,
    ) -> Result<(Vec<Vec<ObserveFrame>>, Vec<Option<Box<RankState>>>), String> {
        if let Some(msg) = &self.poisoned {
            return Err(format!("virtual cluster poisoned: {msg}"));
        }
        for (rank, tx) in self.cmd_tx.iter().enumerate() {
            if tx.send(make(rank)).is_err() {
                // only reachable if a worker died outside a command —
                // poison defensively rather than hang on collect
                self.poisoned = Some("rank worker exited unexpectedly".to_string());
                return Err("virtual cluster poisoned: rank worker exited unexpectedly"
                    .to_string());
            }
        }
        self.collect()
    }

    /// Wait for exactly one reply per rank. Every worker replies once
    /// per command — panicking workers hang up their channels first, so
    /// peers blocked on them cascade-panic and still reply (see the
    /// module docs) — hence this deadlocks only if a worker *hangs*
    /// without panicking, which the watchdog deadline converts into a
    /// poisoning that names the stuck rank(s).
    fn collect(
        &mut self,
    ) -> Result<(Vec<Vec<ObserveFrame>>, Vec<Option<Box<RankState>>>), String> {
        let n = self.slots.len();
        let mut frames = vec![Vec::new(); n];
        let mut states: Vec<Option<Box<RankState>>> = (0..n).map(|_| None).collect();
        let mut replied = vec![false; n];
        let mut root_panic: Option<String> = None;
        let deadline = self.watchdog_timeout_ms.map(Duration::from_millis);
        for _ in 0..n {
            let reply = match deadline {
                Some(d) => self.reply_rx.recv_timeout(d),
                None => self.reply_rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
            };
            match reply {
                Ok(Reply::Done { rank, frames: f, state }) => {
                    replied[rank as usize] = true;
                    frames[rank as usize] = f;
                    states[rank as usize] = state;
                }
                Ok(Reply::Panicked { rank, msg }) => {
                    replied[rank as usize] = true;
                    let cascade = msg.contains("hung up");
                    let full = format!("rank {rank} panicked: {msg}");
                    match &mut root_panic {
                        None => root_panic = Some(full),
                        // a cascade panic must not mask the root cause
                        Some(cur) if cur.contains("hung up") && !cascade => *cur = full,
                        Some(_) => {}
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    root_panic
                        .get_or_insert_with(|| "rank workers terminated unexpectedly".into());
                    break;
                }
                Err(RecvTimeoutError::Timeout) => {
                    // name every rank still owing a reply and detach its
                    // worker: it may be parked forever
                    let mut stuck = Vec::new();
                    for (rank, done) in replied.iter().enumerate() {
                        if !done {
                            self.hung[rank] = true;
                            stuck.push(format!("rank {rank}"));
                        }
                    }
                    let ms = self.watchdog_timeout_ms.unwrap_or(0);
                    root_panic.get_or_insert(format!(
                        "watchdog: no reply within {ms} ms from {}",
                        stuck.join(", ")
                    ));
                    break;
                }
            }
        }
        match root_panic {
            None => Ok((frames, states)),
            Some(msg) => {
                self.poisoned = Some(msg.clone());
                Err(format!("virtual cluster poisoned: {msg}"))
            }
        }
    }
}

impl Drop for Executor {
    /// Dropping the executor (Network drop, with or without an explicit
    /// shutdown) terminates the pool cleanly: idle workers get
    /// `Shutdown`, dead workers' channels error harmlessly, hung
    /// workers are detached, and every other thread is joined.
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Command::Shutdown);
        }
        for (rank, h) in self.handles.drain(..).enumerate() {
            if self.hung.get(rank).copied().unwrap_or(false) {
                drop(h); // watchdog victim: parked forever, never joins
            } else {
                let _ = h.join();
            }
        }
    }
}

/// Build the per-rank command channels, the shared reply channel, and
/// one worker thread per slot (used by both `launch` and `recover`).
/// Workers hold the only reply senders: `reply_rx` disconnects iff
/// every worker exited, which `collect` treats as poisoning.
fn spawn_workers(
    slots: &[Arc<Mutex<RankSlot>>],
) -> (Vec<Sender<Command>>, Receiver<Reply>, Vec<JoinHandle<()>>) {
    let (reply_tx, reply_rx) = channel();
    let mut cmd_tx = Vec::with_capacity(slots.len());
    let mut handles = Vec::with_capacity(slots.len());
    for (rank, slot) in (0_u32..).zip(slots.iter()) {
        let (tx, rx) = channel();
        cmd_tx.push(tx);
        let slot = Arc::clone(slot);
        let reply_tx = reply_tx.clone();
        let h = std::thread::Builder::new()
            .name(format!("rank{rank}"))
            .stack_size(8 << 20)
            .spawn(move || worker(rank, &slot, &rx, &reply_tx))
            .expect("spawn rank worker thread");
        handles.push(h);
    }
    drop(reply_tx);
    (cmd_tx, reply_rx, handles)
}

/// The rank worker main loop: the paper's "simulation phase" process,
/// idling between commands. Every command executes under
/// `catch_unwind`; success replies `Done`, a panic hangs up the rank's
/// channels (unblocking peers) and replies `Panicked` with the payload.
/// A recovered pool's worker may find its slot lock poisoned by its
/// predecessor — the state under it is a consistent pre-command
/// snapshot (the session replays over it), so the lock is recovered,
/// not propagated.
fn worker(
    rank: u32,
    slot: &Arc<Mutex<RankSlot>>,
    cmd_rx: &Receiver<Command>,
    reply_tx: &Sender<Reply>,
) {
    loop {
        let cmd = match cmd_rx.recv() {
            Ok(cmd) => cmd,
            // coordinator gone (executor dropped mid-teardown)
            Err(_) => return,
        };
        let shutdown = matches!(cmd, Command::Shutdown);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut guard = slot.lock().unwrap_or_else(PoisonError::into_inner);
            let RankSlot { proc, comm } = &mut *guard;
            let mut out = CmdOutcome { frames: Vec::new(), state: None, reply_fault: None };
            match cmd {
                Command::Shutdown => {}
                Command::Run { step0, steps, observe } => {
                    proc.set_observe(observe);
                    // capacity is a hint: a (theoretical) overflow of
                    // usize just skips the preallocation
                    let cap = if observe { usize::try_from(steps).unwrap_or(0) } else { 0 };
                    let mut frames = Vec::with_capacity(cap);
                    for k in 0..steps {
                        proc.step(comm, step0 + k);
                        if observe {
                            frames.push(frame_of(proc));
                        }
                    }
                    out.frames = frames;
                }
                Command::Probe => out.frames = vec![frame_of(proc)],
                Command::Reset => {
                    proc.reset();
                    let _ = comm.take_stats();
                }
                Command::SetExternal { area, external } => match area {
                    None => proc.set_external(external),
                    Some(i) => proc.set_area_external(i as usize, external),
                },
                Command::Snapshot => {
                    out.state = Some(Box::new(proc.snapshot_state()));
                }
                Command::Restore { state, rebase_delta } => {
                    // validated coordinator-side; a mismatch reaching
                    // this far is a protocol bug worth poisoning over
                    if let Err(e) = proc.restore_state(&state) {
                        panic!("restore failed on rank {rank}: {e}");
                    }
                    if rebase_delta > 0 {
                        proc.rebase(rebase_delta);
                    }
                }
            }
            // injected reply-time faults (Hang / DelayReply) are
            // consumed here but ACTED ON after the lock drops, so a
            // hung worker never wedges coordinator-side slot readers
            out.reply_fault = proc.take_reply_fault();
            out
        }));
        match result {
            Ok(out) => {
                if shutdown {
                    return;
                }
                match out.reply_fault {
                    Some(FaultMode::Hang) => loop {
                        // never reply, never exit: the watchdog must
                        // diagnose this rank by its silence
                        std::thread::park();
                    },
                    Some(FaultMode::DelayReplyMs(ms)) => {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    Some(FaultMode::Panic) | None => {}
                }
                let reply = Reply::Done { rank, frames: out.frames, state: out.state };
                if reply_tx.send(reply).is_err() {
                    return;
                }
            }
            Err(payload) => {
                let msg = panic_message(&*payload);
                // disconnect our outgoing channels FIRST so any peer
                // blocked on this rank fails over instead of deadlocking
                let mut guard = slot.lock().unwrap_or_else(PoisonError::into_inner);
                guard.comm.hang_up();
                drop(guard);
                let _ = reply_tx.send(Reply::Panicked { rank, msg });
                return;
            }
        }
    }
}

fn frame_of(proc: &RankProcess) -> ObserveFrame {
    let mut phase_ns = [0u64; PHASES.len()];
    for p in PHASES {
        phase_ns[p.index()] = proc.metrics.phase_ns(p);
    }
    ObserveFrame { col_spikes: proc.step_col_spikes().to_vec(), phase_ns }
}
