//! Simulation orchestration: the staged build-once/run-many pipeline
//! ([`SimulationBuilder`] → [`Network`] → [`Session`]), the persistent
//! rank executor driving it, run summaries, and the legacy one-shot
//! [`run_simulation`] compatibility wrapper.

pub(crate) mod executor;
pub mod leader;
pub(crate) mod procpool;
pub mod session;

pub use leader::{AreaTotals, RunSummary};
#[allow(deprecated)]
pub use leader::run_simulation;
pub use session::{Network, RecoveryStats, Session, SimulationBuilder};
