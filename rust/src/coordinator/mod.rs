//! Cluster leader: orchestrates the virtual cluster and aggregates the
//! paper's measurements.

pub mod leader;

pub use leader::{run_simulation, RunSummary};
