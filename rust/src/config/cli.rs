//! Hand-rolled CLI argument parser (no clap in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments and subcommands, with generated usage text. The `dpsnn`
//! binary builds its subcommand table on top of this.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Boolean flags take no value.
    pub is_flag: bool,
    pub default: Option<&'static str>,
}

/// Parsed argument set.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("bad value for --{name}: '{s}' ({e})")),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(name)?.unwrap_or(default))
    }
}

/// Command specification: name, help, options.
pub struct Command {
    pub name: &'static str,
    pub help: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, help: &'static str) -> Self {
        Command { name, help, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: false, default: None });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: false, default: Some(default) });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: true, default: None });
        self
    }

    /// Parse argv (after the subcommand name) against this spec.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        for spec in &self.opts {
            if let Some(d) = spec.default {
                out.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let Some(spec) = self.opts.iter().find(|s| s.name == key) else {
                    return Err(format!(
                        "unknown option --{key} for '{}'\n{}",
                        self.name,
                        self.usage()
                    ));
                };
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    out.flags.push(key.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{key} needs a value"))?
                        }
                    };
                    out.values.insert(key.to_string(), val);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn usage(&self) -> String {
        let mut s = format!("usage: dpsnn {} [options]\n  {}\noptions:\n", self.name, self.help);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{}{kind}\n      {}{def}\n", o.name, o.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("run", "run a simulation")
            .opt("side", "grid side")
            .opt_default("ranks", "1", "number of ranks")
            .flag("verbose", "chatty output")
    }

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_separate_and_inline_values() {
        let a = cmd().parse(&argv(&["--side", "24", "--ranks=8", "--verbose"])).unwrap();
        assert_eq!(a.get("side"), Some("24"));
        assert_eq!(a.get_or("ranks", 0u32).unwrap(), 8);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.get_or("ranks", 0u32).unwrap(), 1);
        assert_eq!(a.get("side"), None);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn unknown_option_errors_with_usage() {
        let e = cmd().parse(&argv(&["--bogus", "1"])).unwrap_err();
        assert!(e.contains("unknown option --bogus"));
        assert!(e.contains("usage: dpsnn run"));
    }

    #[test]
    fn missing_value_and_flag_with_value_error() {
        assert!(cmd().parse(&argv(&["--side"])).is_err());
        assert!(cmd().parse(&argv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn positional_and_typed_errors() {
        let a = cmd().parse(&argv(&["input.toml", "--side", "abc"])).unwrap();
        assert_eq!(a.positional, vec!["input.toml".to_string()]);
        assert!(a.get_parsed::<u32>("side").unwrap_err().contains("bad value"));
    }
}
