//! Configuration subsystem: a minimal TOML parser ([`toml`]), the typed
//! simulation configuration ([`sim`]) with paper presets, and the CLI
//! argument parser ([`cli`]).

pub mod cli;
pub mod sim;
pub mod toml;

pub use sim::{
    AreaParams, ConnParams, ConnRule, DelayDist, DynamicsBackend, ExternalOverride,
    ExternalParams, GridParams, NeuronParams, ProjectionParams, SimConfig, Solver, Stride,
    SynParams, TransportKind,
};
