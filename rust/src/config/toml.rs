//! Minimal TOML parser — the configuration substrate.
//!
//! No serde/toml crates exist in the offline vendor set, so this module
//! implements the subset of TOML the simulator's config files need:
//! `[table]` / `[table.sub]` headers, top-level `[[name]]`
//! array-of-tables (the multi-area `[[area]]`/`[[projection]]` blocks),
//! `key = value` pairs with string, integer, float, boolean and
//! homogeneous-array values, `#` comments, and bare or quoted keys.
//! Values are exposed through a small dynamic [`Value`] tree with typed
//! accessors that report precise errors
//! (`section.key: expected float, found string "x"`).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "boolean",
            Value::Array(_) => "array",
            Value::Table(_) => "table",
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`tau = 20` is a valid float).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }
}

/// Parse error with line information.
#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, TomlError> {
    Err(TomlError { line, msg: msg.into() })
}

/// A parsed document: the root table plus typed lookup helpers.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub root: BTreeMap<String, Value>,
}

impl Doc {
    /// Look up a dotted path like `"network.grid_side"`.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let mut cur: &BTreeMap<String, Value> = &self.root;
        let parts: Vec<&str> = path.split('.').collect();
        for (i, part) in parts.iter().enumerate() {
            let v = cur.get(*part)?;
            if i == parts.len() - 1 {
                return Some(v);
            }
            cur = v.as_table()?;
        }
        None
    }

    fn typed<T>(
        &self,
        path: &str,
        what: &'static str,
        f: impl Fn(&Value) -> Option<T>,
    ) -> Result<T, String> {
        match self.get(path) {
            None => Err(format!("missing config key '{path}'")),
            Some(v) => f(v).ok_or_else(|| {
                format!("config key '{path}': expected {what}, found {}", v.type_name())
            }),
        }
    }

    pub fn str(&self, path: &str) -> Result<String, String> {
        self.typed(path, "string", |v| v.as_str().map(|s| s.to_string()))
    }

    pub fn int(&self, path: &str) -> Result<i64, String> {
        self.typed(path, "integer", Value::as_int)
    }

    pub fn float(&self, path: &str) -> Result<f64, String> {
        self.typed(path, "float", Value::as_float)
    }

    pub fn boolean(&self, path: &str) -> Result<bool, String> {
        self.typed(path, "boolean", Value::as_bool)
    }

    /// Typed lookup with a default when the key is absent.
    pub fn int_or(&self, path: &str, default: i64) -> Result<i64, String> {
        match self.get(path) {
            None => Ok(default),
            Some(_) => self.int(path),
        }
    }

    pub fn float_or(&self, path: &str, default: f64) -> Result<f64, String> {
        match self.get(path) {
            None => Ok(default),
            Some(_) => self.float(path),
        }
    }

    pub fn bool_or(&self, path: &str, default: bool) -> Result<bool, String> {
        match self.get(path) {
            None => Ok(default),
            Some(_) => self.boolean(path),
        }
    }

    pub fn str_or(&self, path: &str, default: &str) -> Result<String, String> {
        match self.get(path) {
            None => Ok(default.to_string()),
            Some(_) => self.str(path),
        }
    }

    /// The items of a top-level `[[name]]` array-of-tables, each wrapped
    /// in its own `Doc` so the typed accessors work on its keys. Empty
    /// when the key is absent; `Err` when it exists but is not an array
    /// of tables.
    pub fn tables(&self, name: &str) -> Result<Vec<Doc>, String> {
        let Some(v) = self.root.get(name) else {
            return Ok(Vec::new());
        };
        let items = v.as_array().ok_or_else(|| {
            format!("config key '{name}': expected array of tables, found {}", v.type_name())
        })?;
        items
            .iter()
            .map(|item| {
                item.as_table().map(|t| Doc { root: t.clone() }).ok_or_else(|| {
                    format!(
                        "config key '{name}': expected array of tables, found array of {}",
                        item.type_name()
                    )
                })
            })
            .collect()
    }
}

/// Where the parser currently writes `key = value` pairs: a `[table]`
/// path, or the last item of a top-level `[[name]]` array-of-tables.
enum Ctx {
    Table(Vec<String>),
    ArrayItem(String),
}

/// Parse a TOML document.
pub fn parse(input: &str) -> Result<Doc, TomlError> {
    let mut doc = Doc::default();
    let mut current = Ctx::Table(Vec::new());
    for (lineno, raw) in input.lines().enumerate() {
        let line = lineno + 1;
        let text = strip_comment(raw).trim().to_string();
        if text.is_empty() {
            continue;
        }
        if let Some(rest) = text.strip_prefix('[') {
            let Some(inner) = rest.strip_suffix(']') else {
                return err(line, "unterminated table header");
            };
            if let Some(arr) = inner.strip_prefix('[') {
                // [[name]]: top-level array of tables (multi-area blocks)
                let Some(name) = arr.strip_suffix(']') else {
                    return err(line, "unterminated array-of-tables header");
                };
                let name = name.trim().trim_matches('"').to_string();
                if name.is_empty() || name.contains('.') {
                    return err(
                        line,
                        format!("bad array-of-tables name '[[{arr}]]' (top-level, undotted)"),
                    );
                }
                let entry = doc
                    .root
                    .entry(name.clone())
                    .or_insert_with(|| Value::Array(Vec::new()));
                match entry {
                    Value::Array(items) => items.push(Value::Table(BTreeMap::new())),
                    other => {
                        return err(
                            line,
                            format!("'{name}' is a {}, not an array of tables", other.type_name()),
                        )
                    }
                }
                current = Ctx::ArrayItem(name);
                continue;
            }
            let parts: Vec<String> = inner
                .split('.')
                .map(|p| p.trim().trim_matches('"').to_string())
                .collect();
            if parts.iter().any(|p| p.is_empty()) {
                return err(line, format!("bad table name '[{inner}]'"));
            }
            ensure_table(&mut doc.root, &parts, line)?;
            current = Ctx::Table(parts);
            continue;
        }
        let Some(eq) = find_unquoted(&text, '=') else {
            return err(line, format!("expected 'key = value', got '{text}'"));
        };
        let key = text[..eq].trim().trim_matches('"').to_string();
        if key.is_empty() {
            return err(line, "empty key");
        }
        let (val, rest) = parse_value(text[eq + 1..].trim(), line)?;
        if !rest.trim().is_empty() {
            return err(line, format!("trailing characters after value: '{rest}'"));
        }
        let table = match &current {
            Ctx::Table(path) => ensure_table(&mut doc.root, path, line)?,
            Ctx::ArrayItem(name) => match doc.root.get_mut(name) {
                Some(Value::Array(items)) => match items.last_mut() {
                    Some(Value::Table(t)) => t,
                    _ => return err(line, format!("'{name}' array holds no open table")),
                },
                _ => return err(line, format!("array-of-tables '{name}' vanished")),
            },
        };
        if table.insert(key.clone(), val).is_some() {
            return err(line, format!("duplicate key '{key}'"));
        }
    }
    Ok(doc)
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// First position of `needle` outside any quoted string.
fn find_unquoted(s: &str, needle: char) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            c if c == needle && !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    line: usize,
) -> Result<&'a mut BTreeMap<String, Value>, TomlError> {
    let mut cur = root;
    for part in path {
        let entry =
            cur.entry(part.clone()).or_insert_with(|| Value::Table(BTreeMap::new()));
        match entry {
            Value::Table(t) => cur = t,
            other => {
                return err(
                    line,
                    format!("'{part}' is a {}, not a table", other.type_name()),
                )
            }
        }
    }
    Ok(cur)
}

/// Parse one value; returns (value, unconsumed remainder).
fn parse_value<'a>(s: &'a str, line: usize) -> Result<(Value, &'a str), TomlError> {
    let s = s.trim_start();
    // no `.unwrap()` on the first char: an empty value token (e.g.
    // `key =`, `a = [1,`, or a bare trailing comma) must surface as a
    // parse error, never a panic
    let Some(first) = s.chars().next() else {
        return err(line, "missing value");
    };
    if first == '"' {
        // string with escapes
        let mut out = String::new();
        let mut chars = s.char_indices().skip(1);
        while let Some((i, c)) = chars.next() {
            match c {
                '"' => return Ok((Value::Str(out), &s[i + 1..])),
                '\\' => match chars.next() {
                    Some((_, 'n')) => out.push('\n'),
                    Some((_, 't')) => out.push('\t'),
                    Some((_, '\\')) => out.push('\\'),
                    Some((_, '"')) => out.push('"'),
                    Some((_, other)) => {
                        return err(line, format!("unknown escape '\\{other}'"))
                    }
                    None => return err(line, "dangling escape"),
                },
                c => out.push(c),
            }
        }
        return err(line, "unterminated string");
    }
    if first == '[' {
        let mut rest = &s[1..];
        let mut items = Vec::new();
        loop {
            rest = rest.trim_start();
            if let Some(r) = rest.strip_prefix(']') {
                return Ok((Value::Array(items), r));
            }
            if rest.is_empty() {
                return err(line, "unterminated array");
            }
            let (v, r) = parse_value(rest, line)?;
            items.push(v);
            rest = r.trim_start();
            if let Some(r) = rest.strip_prefix(',') {
                rest = r;
            } else if !rest.starts_with(']') {
                return err(line, "expected ',' or ']' in array");
            }
        }
    }
    // bare token: bool / int / float
    let end = s
        .char_indices()
        .find(|(_, c)| *c == ',' || *c == ']' || c.is_whitespace())
        .map(|(i, _)| i)
        .unwrap_or(s.len());
    let tok = &s[..end];
    let rest = &s[end..];
    let v = match tok {
        "true" => Value::Bool(true),
        "false" => Value::Bool(false),
        _ => {
            let clean = tok.replace('_', "");
            if !tok.contains('.') && !tok.contains('e') && !tok.contains('E') {
                if let Ok(i) = clean.parse::<i64>() {
                    return Ok((Value::Int(i), rest));
                }
            }
            match clean.parse::<f64>() {
                Ok(f) => Value::Float(f),
                Err(_) => return err(line, format!("cannot parse value '{tok}'")),
            }
        }
    };
    Ok((v, rest))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = parse(
            r#"
# top comment
title = "dpsnn"   # trailing comment
steps = 1_000
dt = 0.001
fast = true

[network]
grid_side = 24
rule = "gaussian"

[network.neuron]
tau_m = 20.0
"#,
        )
        .unwrap();
        assert_eq!(doc.str("title").unwrap(), "dpsnn");
        assert_eq!(doc.int("steps").unwrap(), 1000);
        assert!((doc.float("dt").unwrap() - 0.001).abs() < 1e-12);
        assert!(doc.boolean("fast").unwrap());
        assert_eq!(doc.int("network.grid_side").unwrap(), 24);
        assert_eq!(doc.str("network.rule").unwrap(), "gaussian");
        assert!((doc.float("network.neuron.tau_m").unwrap() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn int_literal_readable_as_float() {
        let doc = parse("x = 3\n").unwrap();
        assert_eq!(doc.float("x").unwrap(), 3.0);
    }

    #[test]
    fn arrays() {
        let doc = parse("procs = [1, 2, 4, 8]\nnames = [\"a\", \"b\"]\nempty = []\n").unwrap();
        let a = doc.get("procs").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(a[3].as_int(), Some(8));
        let n = doc.get("names").unwrap().as_array().unwrap();
        assert_eq!(n[1].as_str(), Some("b"));
        assert!(doc.get("empty").unwrap().as_array().unwrap().is_empty());
    }

    #[test]
    fn string_escapes_and_hash_inside_string() {
        let doc = parse("s = \"a#b\\nc\\\"d\"\n").unwrap();
        assert_eq!(doc.str("s").unwrap(), "a#b\nc\"d");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("x = \n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("[t\n").unwrap_err();
        assert!(e.msg.contains("unterminated"));
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn empty_value_tokens_error_instead_of_panicking() {
        // regression: parse_value used `.chars().next().unwrap()` on the
        // value token; every empty-token shape must be a clean error
        for (input, line) in [
            ("x =", 1),
            ("x = ", 1),
            ("x =\t", 1),
            ("ok = 1\ny =   # only a comment\n", 2),
            ("a = [1,", 1),
            ("a = [", 1),
        ] {
            let e = parse(input).unwrap_err();
            assert_eq!(e.line, line, "input {input:?}");
            assert!(
                e.msg.contains("missing value") || e.msg.contains("unterminated"),
                "input {input:?} gave: {}",
                e.msg
            );
        }
    }

    #[test]
    fn type_errors_are_descriptive() {
        let doc = parse("x = \"hi\"\n").unwrap();
        let e = doc.int("x").unwrap_err();
        assert!(e.contains("expected integer"), "{e}");
        assert!(e.contains("string"), "{e}");
        let e = doc.float("nope").unwrap_err();
        assert!(e.contains("missing"), "{e}");
    }

    #[test]
    fn defaults() {
        let doc = parse("[a]\nx = 5\n").unwrap();
        assert_eq!(doc.int_or("a.x", 1).unwrap(), 5);
        assert_eq!(doc.int_or("a.y", 1).unwrap(), 1);
        assert_eq!(doc.float_or("a.z", 2.5).unwrap(), 2.5);
        assert!(doc.bool_or("a.w", true).unwrap());
        assert_eq!(doc.str_or("a.s", "d").unwrap(), "d");
        // present-but-wrong-type must still error
        assert!(doc.int_or("a", 1).is_err());
    }

    #[test]
    fn array_of_tables_parses_and_reads_back() {
        let doc = parse(
            r#"
[simulation]
seed = 7

[[area]]
name = "v1"
side = 4

[[area]]
name = "v2"
side = 6
rate = 0.5

[[projection]]
source = "v1"
target = "v2"

[run]
mapping = "block"
"#,
        )
        .unwrap();
        // surrounding tables are untouched by the array items
        assert_eq!(doc.int("simulation.seed").unwrap(), 7);
        assert_eq!(doc.str("run.mapping").unwrap(), "block");
        let areas = doc.tables("area").unwrap();
        assert_eq!(areas.len(), 2);
        assert_eq!(areas[0].str("name").unwrap(), "v1");
        assert_eq!(areas[0].int("side").unwrap(), 4);
        assert_eq!(areas[1].str("name").unwrap(), "v2");
        assert!((areas[1].float("rate").unwrap() - 0.5).abs() < 1e-12);
        // typed defaults work inside an item
        assert_eq!(areas[1].int_or("side", 1).unwrap(), 6);
        assert_eq!(areas[0].float_or("rate", 2.0).unwrap(), 2.0);
        let projs = doc.tables("projection").unwrap();
        assert_eq!(projs.len(), 1);
        assert_eq!(projs[0].str("source").unwrap(), "v1");
        // absent name → empty, scalar under the name → error
        assert!(doc.tables("nothing").unwrap().is_empty());
        assert!(parse("x = 1\n").unwrap().tables("x").is_err());
    }

    #[test]
    fn array_of_tables_rejects_bad_shapes() {
        // reopening the name as a scalar or table conflicts
        assert!(parse("[[a]]\nx = 1\n[a]\n").is_err());
        assert!(parse("a = 1\n[[a]]\n").is_err());
        // dotted / unterminated headers
        assert!(parse("[[a.b]]\n").is_err());
        assert!(parse("[[a]\n").is_err());
        // duplicate key inside one item errors; across items it is fine
        assert!(parse("[[a]]\nx = 1\nx = 2\n").is_err());
        assert!(parse("[[a]]\nx = 1\n[[a]]\nx = 2\n").is_ok());
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = parse("a = -65.0\nb = 1e-3\nc = -12\n").unwrap();
        assert_eq!(doc.float("a").unwrap(), -65.0);
        assert!((doc.float("b").unwrap() - 1e-3).abs() < 1e-15);
        assert_eq!(doc.int("c").unwrap(), -12);
    }
}
