//! Typed simulation configuration.
//!
//! Mirrors the paper's experimental setup (§III): a 2D grid of cortical
//! modules ("columns") of 1240 LIF+SFA neurons each (80% excitatory),
//! spaced at α = 100 µm, wired with one of two remote-connectivity rules:
//!
//! * Gaussian (shorter range):   p(r) = A·exp(−r²/2σ²), A=0.05, σ=100 µm
//! * Exponential (longer range): p(r) = A·exp(−r/λ),    A=0.03, λ=290 µm
//!
//! plus a flat 80% same-column connection probability and a 1/1000
//! cutoff on the remote rule, which yields the paper's 7×7 (Gaussian)
//! and 21×21 (exponential) projection stencils (see
//! `connectivity::rules` for how the cutoff interacts with in-column
//! neuron positions to produce exactly those stencil sizes).
//!
//! Every knob is overridable from a TOML file (see `configs/*.toml`) or
//! from CLI flags; presets reproduce the paper's configurations.

use std::sync::Arc;

use crate::config::toml::Doc;
use crate::connectivity::kernel::{self, ConnectivityKernel};

/// Remote-connectivity decay law (paper §III-B).
///
/// The two paper presets. The open extension point is the
/// [`ConnectivityKernel`] trait (`connectivity::kernel`): additional
/// profiles — registered by name or fully custom — ride in
/// [`SimConfig::kernel`] and take precedence over this enum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnRule {
    /// Shorter range: p(r) = A·exp(−r²/2σ²).
    Gaussian,
    /// Longer range: p(r) = A·exp(−r/λ).
    Exponential,
}

impl ConnRule {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "gaussian" | "gauss" => Ok(ConnRule::Gaussian),
            "exponential" | "exp" => Ok(ConnRule::Exponential),
            other => Err(format!("unknown connectivity rule '{other}' (gaussian|exponential)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ConnRule::Gaussian => "gaussian",
            ConnRule::Exponential => "exponential",
        }
    }
}

/// Synaptic-delay distribution (paper §II-B: exponential or uniform).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DelayDist {
    /// Exponential with the given mean, clamped to [min, max].
    Exponential { mean_ms: f64 },
    /// Uniform over [min, max].
    Uniform,
}

/// Parameters of the LIF+SFA neuron (paper eq. 1–2).
#[derive(Clone, Copy, Debug)]
pub struct NeuronParams {
    /// Membrane time constant τm [ms].
    pub tau_m_ms: f64,
    /// Fatigue decay time τc [ms] (SFA / AHP current).
    pub tau_c_ms: f64,
    /// Resting potential E [mV].
    pub e_rest_mv: f64,
    /// Spike threshold Vθ [mV].
    pub v_theta_mv: f64,
    /// Post-spike reset Vr [mV].
    pub v_reset_mv: f64,
    /// Absolute refractory period τarp [ms].
    pub tau_arp_ms: f64,
    /// SFA coupling g_c/C_m [mV per unit c per ms] (0 for inhibitory).
    pub g_c_over_cm: f64,
    /// Fatigue increment per emitted spike α_c.
    pub alpha_c: f64,
}

impl NeuronParams {
    /// Excitatory defaults; SFA active.
    pub fn excitatory() -> Self {
        NeuronParams {
            tau_m_ms: 20.0,
            tau_c_ms: 300.0,
            e_rest_mv: -65.0,
            v_theta_mv: -50.0,
            v_reset_mv: -60.0,
            tau_arp_ms: 2.0,
            g_c_over_cm: 0.02,
            alpha_c: 1.0,
        }
    }

    /// Inhibitory: SFA disabled (paper: "For inhibitory neurons, the SFA
    /// term is set to zero"), faster membrane.
    pub fn inhibitory() -> Self {
        NeuronParams { g_c_over_cm: 0.0, alpha_c: 0.0, tau_m_ms: 10.0, ..Self::excitatory() }
    }
}

/// Connectivity parameters (paper §III-B).
#[derive(Clone, Copy, Debug)]
pub struct ConnParams {
    pub rule: ConnRule,
    /// Peak remote probability A (0.05 gauss / 0.03 exp).
    pub amplitude: f64,
    /// σ [µm] for Gaussian.
    pub sigma_um: f64,
    /// λ [µm] for exponential.
    pub lambda_um: f64,
    /// Same-column connection probability (0.8 → ~990 local synapses).
    pub local_prob: f64,
    /// Remote-rule cutoff: modules whose *best-case* connection
    /// probability is below this are never targeted (1/1000).
    pub cutoff: f64,
    /// Inhibitory neurons project only inside their column (Fig. 2).
    pub inhibitory_local_only: bool,
}

impl ConnParams {
    pub fn gaussian() -> Self {
        ConnParams {
            rule: ConnRule::Gaussian,
            amplitude: 0.05,
            sigma_um: 100.0,
            lambda_um: 290.0,
            local_prob: 0.8,
            cutoff: 1e-3,
            inhibitory_local_only: true,
        }
    }

    pub fn exponential() -> Self {
        ConnParams { rule: ConnRule::Exponential, amplitude: 0.03, ..Self::gaussian() }
    }

    /// Remote connection probability at distance `r_um` (no cutoff).
    ///
    /// Evaluates the `rule` preset's kernel (stack-built, no dispatch
    /// cost). A custom [`SimConfig::kernel`] overrides this for the
    /// whole pipeline — query `SimConfig::kernel_dyn` when the config
    /// is in scope.
    #[inline]
    pub fn prob_at(&self, r_um: f64) -> f64 {
        match self.rule {
            ConnRule::Gaussian => kernel::Gaussian {
                amplitude: self.amplitude,
                sigma_um: self.sigma_um,
            }
            .prob_at(r_um),
            ConnRule::Exponential => kernel::Exponential {
                amplitude: self.amplitude,
                lambda_um: self.lambda_um,
            }
            .prob_at(r_um),
        }
    }
}

/// Synaptic efficacy/delay parameters per projection class.
#[derive(Clone, Copy, Debug)]
pub struct SynParams {
    /// Excitatory efficacy mean [mV] (instantaneous ΔV on arrival).
    pub j_exc_mv: f64,
    /// Inhibitory efficacy mean [mV] (negative).
    pub j_inh_mv: f64,
    /// Relative s.d. of efficacies (gaussian draw, paper §II-B).
    pub j_rel_sd: f64,
    /// External (Poisson) efficacy [mV].
    pub j_ext_mv: f64,
    /// Delay distribution.
    pub delay_dist: DelayDist,
    /// Delay bounds [ms]; also the delay-queue horizon.
    pub delay_min_ms: f64,
    pub delay_max_ms: f64,
}

impl Default for SynParams {
    fn default() -> Self {
        SynParams {
            j_exc_mv: 0.12,
            j_inh_mv: -1.30,
            j_rel_sd: 0.25,
            j_ext_mv: 0.45,
            delay_dist: DelayDist::Exponential { mean_ms: 5.0 },
            delay_min_ms: 1.0,
            delay_max_ms: 40.0,
        }
    }
}

/// External (thalamo-cortical) stimulus: per-neuron Poisson bundle.
#[derive(Clone, Copy, Debug)]
pub struct ExternalParams {
    /// Number of external synapses afferent to each neuron. Table I's
    /// "total equivalent" minus recurrent synapses ⇒ ~420 per neuron.
    pub synapses_per_neuron: u32,
    /// Mean firing rate of each external synapse [Hz].
    pub rate_hz: f64,
}

impl Default for ExternalParams {
    fn default() -> Self {
        ExternalParams { synapses_per_neuron: 420, rate_hz: 3.0 }
    }
}

/// Grid/network geometry (paper §III-B, Table I).
#[derive(Clone, Copy, Debug)]
pub struct GridParams {
    /// Columns along x.
    pub nx: u32,
    /// Columns along y.
    pub ny: u32,
    /// Inter-column spacing α [µm].
    pub spacing_um: f64,
    /// Neurons per column (1240).
    pub neurons_per_column: u32,
    /// Excitatory fraction (0.8).
    pub exc_fraction: f64,
}

impl GridParams {
    pub fn square(side: u32) -> Self {
        GridParams {
            nx: side,
            ny: side,
            spacing_um: 100.0,
            neurons_per_column: 1240,
            exc_fraction: 0.8,
        }
    }

    pub fn columns(&self) -> u64 {
        self.nx as u64 * self.ny as u64
    }

    pub fn neurons(&self) -> u64 {
        self.columns() * self.neurons_per_column as u64
    }

    pub fn exc_per_column(&self) -> u32 {
        (self.neurons_per_column as f64 * self.exc_fraction).round() as u32
    }

    pub fn inh_per_column(&self) -> u32 {
        self.neurons_per_column - self.exc_per_column()
    }
}

/// One named area of a multi-area atlas configuration: its own grid
/// and intra-areal connectivity, plus an optional external-drive
/// override (None → the global [`SimConfig::external`] drive).
///
/// Synaptic efficacies/delays ([`SynParams`]) and neuron parameters are
/// global: the atlas composes areas of the same cortical model, wired
/// differently.
#[derive(Clone, Debug)]
pub struct AreaParams {
    pub name: String,
    pub grid: GridParams,
    /// Intra-areal connectivity (local probability + remote kernel).
    pub conn: ConnParams,
    /// Custom intra-areal kernel; overrides `conn.rule` (same contract
    /// as [`SimConfig::kernel`]).
    pub kernel: Option<Arc<dyn ConnectivityKernel>>,
    /// Per-area external Poisson drive; `None` uses the global drive.
    pub external: Option<ExternalParams>,
}

/// A typed inter-areal projection: source area → target area.
///
/// Source columns map **topographically** into the target area's column
/// grid — `mapped = offset + source_coords / stride` per axis — and the
/// projection then spreads **laterally** around the mapped column with
/// a [`ConnectivityKernel`] evaluated in the target area's own frame
/// (the source neuron's in-column jitter rides along, scaled to the
/// target spacing). Transmission delays follow a constant-plus-distance
/// model: `delay = delay_base_ms + r / velocity_um_per_ms`, clamped to
/// the global `[delay_min_ms, delay_max_ms]` window.
#[derive(Clone, Debug)]
pub struct ProjectionParams {
    /// Source area name.
    pub source: String,
    /// Target area name.
    pub target: String,
    /// Lateral-spread kernel parameters (amplitude/σ/λ/cutoff; the
    /// `local_prob` and `inhibitory_local_only` fields are unused here).
    pub conn: ConnParams,
    /// Custom lateral-spread kernel; overrides `conn.rule`.
    pub kernel: Option<Arc<dyn ConnectivityKernel>>,
    /// Topographic column-mapping offset (target columns).
    pub offset: (i32, i32),
    /// Topographic down-sampling stride (≥ 1 per axis): source column
    /// (cx, cy) maps to target column (offset + (cx/sx, cy/sy)).
    pub stride: (u32, u32),
    /// Only excitatory source neurons project (the long-range cortical
    /// default; Fig. 2's inhibitory-local rule extended across areas).
    pub excitatory_only: bool,
    /// Constant part of the inter-areal delay [ms] (the long-range
    /// tract); clamped into the global delay window.
    pub delay_base_ms: f64,
    /// Conduction velocity of the lateral-spread distance term
    /// [µm/ms]; 1000 µm/ms = 1 m/s.
    pub velocity_um_per_ms: f64,
    /// Multiplier on the drawn synaptic efficacies (> 0): inter-areal
    /// synapses are routinely modeled stronger (or weaker) than the
    /// local plexus without touching the global `SynParams`.
    pub weight_scale: f64,
}

impl ProjectionParams {
    /// A projection with the paper-Gaussian lateral spread, identity
    /// topography, excitatory-only sources and a 2 ms tract delay.
    pub fn new(source: &str, target: &str) -> Self {
        ProjectionParams {
            source: source.to_string(),
            target: target.to_string(),
            conn: ConnParams::gaussian(),
            kernel: None,
            offset: (0, 0),
            stride: (1, 1),
            excitatory_only: true,
            delay_base_ms: 2.0,
            velocity_um_per_ms: 1000.0,
            weight_scale: 1.0,
        }
    }

    pub fn weight_scale(mut self, scale: f64) -> Self {
        self.weight_scale = scale;
        self
    }

    pub fn offset(mut self, dx: i32, dy: i32) -> Self {
        self.offset = (dx, dy);
        self
    }

    pub fn stride(mut self, sx: u32, sy: u32) -> Self {
        self.stride = (sx, sy);
        self
    }

    pub fn conn(mut self, conn: ConnParams) -> Self {
        self.conn = conn;
        self
    }

    pub fn kernel(mut self, kernel: Arc<dyn ConnectivityKernel>) -> Self {
        self.kernel = Some(kernel);
        self
    }

    pub fn excitatory_only(mut self, on: bool) -> Self {
        self.excitatory_only = on;
        self
    }

    pub fn delay(mut self, base_ms: f64, velocity_um_per_ms: f64) -> Self {
        self.delay_base_ms = base_ms;
        self.velocity_um_per_ms = velocity_um_per_ms;
        self
    }

    /// The lateral-spread kernel: custom when set, else `conn.rule`.
    pub fn kernel_dyn(&self) -> Arc<dyn ConnectivityKernel> {
        match &self.kernel {
            Some(k) => Arc::clone(k),
            None => kernel::from_rule(&self.conn),
        }
    }
}

/// Which neuron integrator the engine uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// Exact event-driven integration in Rust (paper's approach).
    EventDriven,
    /// Batched per-timestep update through the AOT-compiled XLA artifact
    /// (L1 Pallas kernel lowered to HLO, executed via PJRT).
    Xla,
}

impl Solver {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "event" | "event-driven" => Ok(Solver::EventDriven),
            "xla" => Ok(Solver::Xla),
            other => Err(format!("unknown solver '{other}' (event|xla)")),
        }
    }
}

/// Top-level simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub grid: GridParams,
    pub conn: ConnParams,
    pub syn: SynParams,
    pub exc: NeuronParams,
    pub inh: NeuronParams,
    pub external: ExternalParams,
    /// Time-driven communication step [ms] (paper: 1 ms).
    pub dt_ms: f64,
    /// Simulated duration [ms].
    pub duration_ms: f64,
    /// Number of (virtual MPI) ranks.
    pub ranks: u32,
    /// Global RNG seed — network is a pure function of this (any ranks).
    pub seed: u64,
    /// STDP plasticity (paper: disabled for all scaling measurements).
    pub plasticity: bool,
    pub solver: Solver,
    /// Custom connectivity kernel; overrides `conn.rule` everywhere
    /// (stencil, synapse generation, analytics) when set. `None` means
    /// "use the preset named by `conn.rule`".
    pub kernel: Option<Arc<dyn ConnectivityKernel>>,
    /// Multi-area atlas: the named areas, in order. **Empty means the
    /// legacy single-grid world** described by `grid`/`conn`/`kernel`
    /// (normalized to a one-area atlas by [`area_list`](Self::area_list)
    /// — the single-grid path and the one-area atlas are the same code
    /// path, bit for bit). When non-empty, `grid`/`conn`/`kernel` serve
    /// only as the defaults areas inherit.
    pub areas: Vec<AreaParams>,
    /// Inter-areal projections (require ≥ 1 named area… or 1: an area
    /// may project onto itself as a second long-range system).
    pub projections: Vec<ProjectionParams>,
}

impl SimConfig {
    /// Paper-preset: Gaussian connectivity on a `side`×`side` grid.
    pub fn gaussian(side: u32) -> Self {
        SimConfig {
            grid: GridParams::square(side),
            conn: ConnParams::gaussian(),
            syn: SynParams::default(),
            exc: NeuronParams::excitatory(),
            inh: NeuronParams::inhibitory(),
            external: ExternalParams::default(),
            dt_ms: 1.0,
            duration_ms: 1000.0,
            ranks: 1,
            seed: 42,
            plasticity: false,
            solver: Solver::EventDriven,
            kernel: None,
            areas: Vec::new(),
            projections: Vec::new(),
        }
    }

    /// Paper-preset: exponential connectivity on a `side`×`side` grid.
    pub fn exponential(side: u32) -> Self {
        SimConfig { conn: ConnParams::exponential(), ..Self::gaussian(side) }
    }

    /// A small configuration for tests: tiny grid, reduced columns.
    pub fn test_small() -> Self {
        let mut c = Self::gaussian(4);
        c.grid.neurons_per_column = 50;
        c.external.synapses_per_neuron = 20;
        c.duration_ms = 50.0;
        c
    }

    /// Number of delay slots of `dt_ms` needed by the delay queues.
    pub fn delay_slots(&self) -> usize {
        (self.syn.delay_max_ms / self.dt_ms).ceil() as usize + 1
    }

    /// The connectivity kernel driving construction: the custom kernel
    /// when set, else the preset named by `conn.rule`.
    pub fn kernel_dyn(&self) -> Arc<dyn ConnectivityKernel> {
        match &self.kernel {
            Some(k) => Arc::clone(k),
            None => kernel::from_rule(&self.conn),
        }
    }

    /// Name of the effective connectivity kernel.
    pub fn kernel_name(&self) -> String {
        match &self.kernel {
            Some(k) => k.name().to_string(),
            None => self.conn.rule.name().to_string(),
        }
    }

    /// The normalized area list: `areas` when configured, else the
    /// legacy single grid as a one-area atlas ("area0" with this
    /// config's `grid`/`conn`/`kernel` and the global external drive).
    /// Everything downstream of configuration — geometry, synapse
    /// generation, the engine — consumes this view, so the single-grid
    /// path *is* the one-area atlas path.
    pub fn area_list(&self) -> Vec<AreaParams> {
        if self.areas.is_empty() {
            vec![AreaParams {
                name: "area0".to_string(),
                grid: self.grid,
                conn: self.conn,
                kernel: self.kernel.clone(),
                external: None,
            }]
        } else {
            self.areas.clone()
        }
    }

    /// The atlas geometry of [`area_list`](Self::area_list).
    pub fn atlas(&self) -> crate::geometry::Atlas {
        crate::geometry::Atlas::new(
            self.area_list().into_iter().map(|a| (a.name, a.grid)).collect(),
        )
    }

    /// Total neurons across the atlas (equals `grid.neurons()` for the
    /// legacy single-grid configuration).
    pub fn total_neurons(&self) -> u64 {
        if self.areas.is_empty() {
            self.grid.neurons()
        } else {
            self.areas.iter().map(|a| a.grid.neurons()).sum()
        }
    }

    /// Load from a parsed TOML document; missing keys keep preset values.
    pub fn from_doc(doc: &Doc) -> Result<Self, String> {
        let rule_name = doc.str_or("connectivity.rule", "gaussian")?;
        let mut cfg = match ConnRule::parse(&rule_name) {
            Ok(ConnRule::Gaussian) => Self::gaussian(24),
            Ok(ConnRule::Exponential) => Self::exponential(24),
            // registered non-enum kernel: resolved below, once the
            // numeric connectivity overrides have been applied
            Err(_) => Self::gaussian(24),
        };
        let g = &mut cfg.grid;
        g.nx = doc.int_or("network.nx", doc.int_or("network.side", g.nx as i64)?)? as u32;
        g.ny = doc.int_or("network.ny", doc.int_or("network.side", g.ny as i64)?)? as u32;
        g.spacing_um = doc.float_or("network.spacing_um", g.spacing_um)?;
        g.neurons_per_column =
            doc.int_or("network.neurons_per_column", g.neurons_per_column as i64)? as u32;
        g.exc_fraction = doc.float_or("network.exc_fraction", g.exc_fraction)?;

        let c = &mut cfg.conn;
        c.amplitude = doc.float_or("connectivity.amplitude", c.amplitude)?;
        c.sigma_um = doc.float_or("connectivity.sigma_um", c.sigma_um)?;
        c.lambda_um = doc.float_or("connectivity.lambda_um", c.lambda_um)?;
        c.local_prob = doc.float_or("connectivity.local_prob", c.local_prob)?;
        c.cutoff = doc.float_or("connectivity.cutoff", c.cutoff)?;
        c.inhibitory_local_only =
            doc.bool_or("connectivity.inhibitory_local_only", c.inhibitory_local_only)?;

        if ConnRule::parse(&rule_name).is_err() {
            cfg.kernel = Some(kernel::from_doc(&rule_name, doc, &cfg.conn)?);
        }

        let s = &mut cfg.syn;
        s.j_exc_mv = doc.float_or("synapse.j_exc_mv", s.j_exc_mv)?;
        s.j_inh_mv = doc.float_or("synapse.j_inh_mv", s.j_inh_mv)?;
        s.j_rel_sd = doc.float_or("synapse.j_rel_sd", s.j_rel_sd)?;
        s.j_ext_mv = doc.float_or("synapse.j_ext_mv", s.j_ext_mv)?;
        s.delay_min_ms = doc.float_or("synapse.delay_min_ms", s.delay_min_ms)?;
        s.delay_max_ms = doc.float_or("synapse.delay_max_ms", s.delay_max_ms)?;
        match doc.str_or("synapse.delay_dist", "exponential")?.as_str() {
            "uniform" => s.delay_dist = DelayDist::Uniform,
            "exponential" => {
                let mean = doc.float_or("synapse.delay_mean_ms", 5.0)?;
                s.delay_dist = DelayDist::Exponential { mean_ms: mean };
            }
            other => return Err(format!("unknown delay_dist '{other}'")),
        }

        for (np, sect) in [(&mut cfg.exc, "neuron.exc"), (&mut cfg.inh, "neuron.inh")] {
            np.tau_m_ms = doc.float_or(&format!("{sect}.tau_m_ms"), np.tau_m_ms)?;
            np.tau_c_ms = doc.float_or(&format!("{sect}.tau_c_ms"), np.tau_c_ms)?;
            np.e_rest_mv = doc.float_or(&format!("{sect}.e_rest_mv"), np.e_rest_mv)?;
            np.v_theta_mv = doc.float_or(&format!("{sect}.v_theta_mv"), np.v_theta_mv)?;
            np.v_reset_mv = doc.float_or(&format!("{sect}.v_reset_mv"), np.v_reset_mv)?;
            np.tau_arp_ms = doc.float_or(&format!("{sect}.tau_arp_ms"), np.tau_arp_ms)?;
            np.g_c_over_cm = doc.float_or(&format!("{sect}.g_c_over_cm"), np.g_c_over_cm)?;
            np.alpha_c = doc.float_or(&format!("{sect}.alpha_c"), np.alpha_c)?;
        }

        cfg.external.synapses_per_neuron = doc
            .int_or("external.synapses_per_neuron", cfg.external.synapses_per_neuron as i64)?
            as u32;
        cfg.external.rate_hz = doc.float_or("external.rate_hz", cfg.external.rate_hz)?;

        cfg.dt_ms = doc.float_or("simulation.dt_ms", cfg.dt_ms)?;
        cfg.duration_ms = doc.float_or("simulation.duration_ms", cfg.duration_ms)?;
        cfg.ranks = doc.int_or("simulation.ranks", cfg.ranks as i64)? as u32;
        cfg.seed = doc.int_or("simulation.seed", cfg.seed as i64)? as u64;
        cfg.plasticity = doc.bool_or("simulation.plasticity", cfg.plasticity)?;
        cfg.solver = Solver::parse(&doc.str_or("simulation.solver", "event")?)?;

        // -- multi-area atlas: [[area]] / [[projection]] blocks --------
        // Areas inherit the already-resolved global [network] and
        // [connectivity] values as their defaults; every key may be
        // overridden per block. A config without [[area]] stays the
        // legacy single grid (areas empty ⇒ one-area atlas).
        for (i, area) in doc.tables("area")?.iter().enumerate() {
            let name = area
                .str_or("name", "")?
                .trim()
                .to_string();
            if name.is_empty() {
                return Err(format!("[[area]] #{}: missing 'name'", i + 1));
            }
            let mut g = cfg.grid;
            g.nx = area.int_or("nx", area.int_or("side", g.nx as i64)?)? as u32;
            g.ny = area.int_or("ny", area.int_or("side", g.ny as i64)?)? as u32;
            g.spacing_um = area.float_or("spacing_um", g.spacing_um)?;
            g.neurons_per_column =
                area.int_or("neurons_per_column", g.neurons_per_column as i64)? as u32;
            g.exc_fraction = area.float_or("exc_fraction", g.exc_fraction)?;
            let (conn, kern) = conn_from_sub(area, &cfg.conn, cfg.kernel.clone())?;
            let external = match (
                area.get("external_synapses_per_neuron").is_some(),
                area.get("external_rate_hz").is_some(),
            ) {
                (false, false) => None,
                _ => Some(ExternalParams {
                    synapses_per_neuron: area.int_or(
                        "external_synapses_per_neuron",
                        cfg.external.synapses_per_neuron as i64,
                    )? as u32,
                    rate_hz: area.float_or("external_rate_hz", cfg.external.rate_hz)?,
                }),
            };
            cfg.areas.push(AreaParams { name, grid: g, conn, kernel: kern, external });
        }
        for (i, proj) in doc.tables("projection")?.iter().enumerate() {
            let source = proj.str_or("source", "")?;
            let target = proj.str_or("target", "")?;
            if source.is_empty() || target.is_empty() {
                return Err(format!("[[projection]] #{}: missing 'source'/'target'", i + 1));
            }
            let d = ProjectionParams::new(&source, &target);
            let (conn, kern) = conn_from_sub(proj, &d.conn, None)?;
            cfg.projections.push(ProjectionParams {
                source,
                target,
                conn,
                kernel: kern,
                offset: (
                    proj.int_or("offset_x", d.offset.0 as i64)? as i32,
                    proj.int_or("offset_y", d.offset.1 as i64)? as i32,
                ),
                stride: (
                    proj.int_or("stride_x", d.stride.0 as i64)? as u32,
                    proj.int_or("stride_y", d.stride.1 as i64)? as u32,
                ),
                excitatory_only: proj.bool_or("excitatory_only", d.excitatory_only)?,
                delay_base_ms: proj.float_or("delay_base_ms", d.delay_base_ms)?,
                velocity_um_per_ms: proj
                    .float_or("velocity_um_per_ms", d.velocity_um_per_ms)?,
                weight_scale: proj.float_or("weight_scale", d.weight_scale)?,
            });
        }

        cfg.validate()?;
        Ok(cfg)
    }

    fn validate_grid(g: &GridParams, what: &str) -> Result<(), String> {
        if g.nx == 0 || g.ny == 0 {
            return Err(format!("{what}: grid must be non-empty"));
        }
        if g.neurons_per_column == 0 {
            return Err(format!("{what}: neurons_per_column must be > 0"));
        }
        if !(0.0..=1.0).contains(&g.exc_fraction) {
            return Err(format!("{what}: exc_fraction must be in [0,1]"));
        }
        Ok(())
    }

    fn validate_conn(c: &ConnParams, what: &str) -> Result<(), String> {
        if !(0.0..=1.0).contains(&c.local_prob) {
            return Err(format!("{what}: local_prob must be in [0,1]"));
        }
        if c.amplitude <= 0.0 || c.amplitude > 1.0 {
            return Err(format!("{what}: connectivity amplitude must be in (0,1]"));
        }
        if c.cutoff <= 0.0 {
            return Err(format!("{what}: cutoff must be > 0"));
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        Self::validate_grid(&self.grid, "network")?;
        Self::validate_conn(&self.conn, "connectivity")?;
        // -- atlas-specific checks ------------------------------------
        for (i, a) in self.areas.iter().enumerate() {
            let what = format!("area '{}'", a.name);
            if a.name.is_empty() {
                return Err(format!("area #{}: empty name", i + 1));
            }
            if self.areas[..i].iter().any(|b| b.name == a.name) {
                return Err(format!("duplicate area name '{}'", a.name));
            }
            Self::validate_grid(&a.grid, &what)?;
            Self::validate_conn(&a.conn, &what)?;
            if self.ranks as u64 > a.grid.columns() {
                return Err(format!(
                    "ranks ({}) exceed columns ({}) of area '{}': every area is \
                     decomposed over all ranks",
                    self.ranks,
                    a.grid.columns(),
                    a.name
                ));
            }
        }
        if !self.projections.is_empty() && self.areas.is_empty() {
            return Err("projections require named [[area]] blocks".into());
        }
        for p in &self.projections {
            let what = format!("projection '{}'->'{}'", p.source, p.target);
            for name in [&p.source, &p.target] {
                if !self.areas.iter().any(|a| &a.name == name) {
                    return Err(format!("{what}: unknown area '{name}'"));
                }
            }
            Self::validate_conn(&p.conn, &what)?;
            if p.stride.0 == 0 || p.stride.1 == 0 {
                return Err(format!("{what}: stride must be >= 1"));
            }
            if !p.delay_base_ms.is_finite() || p.delay_base_ms < 0.0 {
                return Err(format!("{what}: delay_base_ms must be finite and >= 0"));
            }
            if p.velocity_um_per_ms.is_nan() || p.velocity_um_per_ms <= 0.0 {
                return Err(format!("{what}: velocity_um_per_ms must be > 0"));
            }
            if !p.weight_scale.is_finite() || p.weight_scale <= 0.0 {
                return Err(format!("{what}: weight_scale must be finite and > 0"));
            }
        }
        // AER wire spikes and synapse endpoints carry gids as u32
        if self.total_neurons() > u32::MAX as u64 + 1 {
            return Err(format!(
                "total neurons ({}) exceed the u32 gid space of the AER wire format",
                self.total_neurons()
            ));
        }
        if self.dt_ms <= 0.0 || self.duration_ms < 0.0 {
            return Err("dt/duration must be positive".into());
        }
        if self.syn.delay_min_ms < self.dt_ms {
            return Err(format!(
                "delay_min_ms ({}) must be >= dt_ms ({}): a spike emitted in step t \
                 is delivered at t+delay, and the exchange happens once per dt",
                self.syn.delay_min_ms, self.dt_ms
            ));
        }
        if self.syn.delay_max_ms < self.syn.delay_min_ms {
            return Err("delay_max_ms < delay_min_ms".into());
        }
        if self.syn.delay_max_ms / self.dt_ms > u16::MAX as f64 {
            return Err(format!(
                "delay_max_ms / dt_ms = {:.0} exceeds the {}-step delay-slot range \
                 (delays are precomputed in whole dt-steps as u16): raise dt_ms or \
                 lower delay_max_ms",
                self.syn.delay_max_ms / self.dt_ms,
                u16::MAX
            ));
        }
        if self.ranks == 0 {
            return Err("ranks must be >= 1".into());
        }
        // per-area rank bounds are checked above; the legacy grid bound
        // applies only when the legacy grid is the world
        if self.areas.is_empty() && self.ranks as u64 > self.grid.columns() {
            return Err(format!(
                "ranks ({}) exceed columns ({}): the spatial mapping assigns whole \
                 columns to ranks",
                self.ranks,
                self.grid.columns()
            ));
        }
        Ok(())
    }
}

/// Resolve connectivity parameters from one `[[area]]`/`[[projection]]`
/// block: numeric keys override `base`, and `rule` selects either a
/// preset (enum) or a registered kernel name resolved against the
/// overridden numbers. `base_kernel` is the inherited custom kernel
/// (kept when the block names no rule of its own).
fn conn_from_sub(
    sub: &Doc,
    base: &ConnParams,
    base_kernel: Option<Arc<dyn ConnectivityKernel>>,
) -> Result<(ConnParams, Option<Arc<dyn ConnectivityKernel>>), String> {
    let mut conn = *base;
    conn.amplitude = sub.float_or("amplitude", conn.amplitude)?;
    conn.sigma_um = sub.float_or("sigma_um", conn.sigma_um)?;
    conn.lambda_um = sub.float_or("lambda_um", conn.lambda_um)?;
    conn.local_prob = sub.float_or("local_prob", conn.local_prob)?;
    conn.cutoff = sub.float_or("cutoff", conn.cutoff)?;
    conn.inhibitory_local_only =
        sub.bool_or("inhibitory_local_only", conn.inhibitory_local_only)?;
    match sub.get("rule") {
        None => {
            // Inherited registered kernel + per-block numeric overrides:
            // re-resolve the kernel by name against the overridden
            // numbers, otherwise the block's sigma/lambda/amplitude edits
            // would silently apply only to validation, not to the wiring.
            // (Kernel-specific extras like lambda_near_um are registry
            // defaults after re-resolution; set `rule` in the block to
            // control them per area.)
            let numeric_override = ["amplitude", "sigma_um", "lambda_um"]
                .iter()
                .any(|k| sub.get(k).is_some());
            let kernel = match base_kernel {
                Some(k) if numeric_override => {
                    Some(kernel::builtin(k.name(), &conn).unwrap_or(k))
                }
                other => other,
            };
            Ok((conn, kernel))
        }
        Some(_) => {
            let rule_name = sub.str("rule")?;
            match ConnRule::parse(&rule_name) {
                Ok(rule) => {
                    conn.rule = rule;
                    Ok((conn, None))
                }
                Err(_) => {
                    let k = kernel::resolve(&rule_name, &conn)?;
                    Ok((conn, Some(k)))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;

    #[test]
    fn presets_match_paper_parameters() {
        let g = SimConfig::gaussian(24);
        assert_eq!(g.conn.amplitude, 0.05);
        assert_eq!(g.conn.sigma_um, 100.0);
        assert_eq!(g.grid.neurons_per_column, 1240);
        assert_eq!(g.grid.exc_per_column(), 992);
        assert_eq!(g.grid.inh_per_column(), 248);
        assert_eq!(g.grid.columns(), 576);
        assert_eq!(g.grid.neurons(), 714_240);
        let e = SimConfig::exponential(48);
        assert_eq!(e.conn.amplitude, 0.03);
        assert_eq!(e.conn.lambda_um, 290.0);
        assert_eq!(e.grid.neurons(), 2_856_960); // 2.9 M in Table I
    }

    #[test]
    fn probability_laws() {
        let g = ConnParams::gaussian();
        assert!((g.prob_at(0.0) - 0.05).abs() < 1e-12);
        assert!((g.prob_at(100.0) - 0.05 * (-0.5f64).exp()).abs() < 1e-12);
        let e = ConnParams::exponential();
        assert!((e.prob_at(0.0) - 0.03).abs() < 1e-12);
        assert!((e.prob_at(290.0) - 0.03 * (-1.0f64).exp()).abs() < 1e-12);
        // exponential is the longer-range law
        assert!(e.prob_at(500.0) > g.prob_at(500.0));
    }

    #[test]
    fn from_doc_roundtrip_and_overrides() {
        let doc = toml::parse(
            r#"
[network]
side = 8
neurons_per_column = 100

[connectivity]
rule = "exponential"
lambda_um = 240.0

[simulation]
ranks = 4
duration_ms = 123.0
solver = "event"
"#,
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.grid.nx, 8);
        assert_eq!(cfg.grid.neurons_per_column, 100);
        assert_eq!(cfg.conn.rule, ConnRule::Exponential);
        assert_eq!(cfg.conn.lambda_um, 240.0);
        assert_eq!(cfg.conn.amplitude, 0.03); // preset kept
        assert_eq!(cfg.ranks, 4);
        assert_eq!(cfg.duration_ms, 123.0);
    }

    #[test]
    fn from_doc_resolves_registered_kernels() {
        let doc = toml::parse(
            r#"
[connectivity]
rule = "doubly-exponential"
lambda_near_um = 120.0
lambda_far_um = 600.0
mix = 0.6
"#,
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let k = cfg.kernel_dyn();
        assert_eq!(k.name(), "doubly-exponential");
        assert_eq!(cfg.kernel_name(), "doubly-exponential");
        // p(0) = A (mix + 1 − mix) = amplitude
        assert!((k.prob_at(0.0) - cfg.conn.amplitude).abs() < 1e-12);

        let doc = toml::parse("[connectivity]\nrule = \"flat-disc\"\ndisc_radius_um = 150.0\n")
            .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.kernel_dyn().name(), "flat-disc");
        assert_eq!(cfg.kernel_dyn().prob_at(150.0), cfg.conn.amplitude);
        assert_eq!(cfg.kernel_dyn().prob_at(151.0), 0.0);

        let doc = toml::parse("[connectivity]\nrule = \"banana\"\n").unwrap();
        let err = SimConfig::from_doc(&doc).unwrap_err();
        assert!(err.contains("banana") && err.contains("flat-disc"), "{err}");

        // enum presets keep kernel = None (legacy path untouched)
        let cfg = SimConfig::gaussian(8);
        assert!(cfg.kernel.is_none());
        assert_eq!(cfg.kernel_dyn().name(), "gaussian");
    }

    #[test]
    fn area_and_projection_blocks_parse_with_inheritance() {
        let doc = toml::parse(
            r#"
[network]
side = 6
neurons_per_column = 50

[connectivity]
rule = "gaussian"
amplitude = 0.04

[external]
synapses_per_neuron = 80
rate_hz = 10.0

[[area]]
name = "v1"

[[area]]
name = "v2"
side = 4
rule = "exponential"
external_rate_hz = 0.0

[[projection]]
source = "v1"
target = "v2"
rule = "exponential"
lambda_um = 200.0
offset_x = -1
stride_x = 2
excitatory_only = false
delay_base_ms = 3.0
velocity_um_per_ms = 500.0

[simulation]
ranks = 2
"#,
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.areas.len(), 2);
        // v1 inherits the global grid + connectivity
        assert_eq!(cfg.areas[0].name, "v1");
        assert_eq!(cfg.areas[0].grid.nx, 6);
        assert_eq!(cfg.areas[0].grid.neurons_per_column, 50);
        assert_eq!(cfg.areas[0].conn.rule, ConnRule::Gaussian);
        assert_eq!(cfg.areas[0].conn.amplitude, 0.04);
        assert!(cfg.areas[0].external.is_none());
        // v2 overrides grid side, rule and the external drive
        assert_eq!(cfg.areas[1].grid.nx, 4);
        assert_eq!(cfg.areas[1].conn.rule, ConnRule::Exponential);
        let ext = cfg.areas[1].external.unwrap();
        assert_eq!(ext.rate_hz, 0.0);
        assert_eq!(ext.synapses_per_neuron, 80); // inherited half
        // projection
        assert_eq!(cfg.projections.len(), 1);
        let p = &cfg.projections[0];
        assert_eq!((p.source.as_str(), p.target.as_str()), ("v1", "v2"));
        assert_eq!(p.conn.rule, ConnRule::Exponential);
        assert_eq!(p.conn.lambda_um, 200.0);
        assert_eq!(p.offset, (-1, 0));
        assert_eq!(p.stride, (2, 1));
        assert!(!p.excitatory_only);
        assert_eq!(p.delay_base_ms, 3.0);
        assert_eq!(p.velocity_um_per_ms, 500.0);
        // atlas view
        let atlas = cfg.atlas();
        assert_eq!(atlas.len(), 2);
        assert_eq!(atlas.columns(), 36 + 16);
        assert_eq!(cfg.total_neurons(), (36 + 16) * 50);
        // legacy configs normalize to a one-area atlas
        let legacy = SimConfig::test_small();
        let one = legacy.area_list();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].grid.nx, legacy.grid.nx);
        assert_eq!(legacy.atlas().neurons(), legacy.grid.neurons());
    }

    #[test]
    fn area_blocks_resolve_registered_kernels() {
        let doc = toml::parse(
            "[[area]]\nname = \"a\"\nside = 4\nrule = \"flat-disc\"\nsigma_um = 50.0\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let k = cfg.areas[0].kernel.as_ref().expect("kernel resolved");
        assert_eq!(k.name(), "flat-disc");
        // 3σ disc radius derives from the overridden σ
        assert_eq!(k.prob_at(150.0), cfg.areas[0].conn.amplitude);
        assert_eq!(k.prob_at(151.0), 0.0);
    }

    #[test]
    fn area_numeric_overrides_rebind_an_inherited_registered_kernel() {
        // global rule is a registered (non-preset) kernel; an [[area]]
        // block overriding sigma_um without naming a rule must get a
        // kernel resolved against ITS numbers, not the stale global one
        let doc = toml::parse(
            "[connectivity]\nrule = \"flat-disc\"\nsigma_um = 100.0\n\n\
             [[area]]\nname = \"a\"\nside = 4\nsigma_um = 50.0\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let k = cfg.areas[0].kernel.as_ref().expect("kernel inherited");
        assert_eq!(k.name(), "flat-disc");
        // 3σ disc from the AREA's σ = 50 → radius 150, not 300
        assert_eq!(k.prob_at(150.0), cfg.areas[0].conn.amplitude);
        assert_eq!(k.prob_at(151.0), 0.0);
        // without numeric overrides the inherited kernel is kept as-is
        let doc = toml::parse(
            "[connectivity]\nrule = \"flat-disc\"\nsigma_um = 100.0\n\n\
             [[area]]\nname = \"a\"\nside = 4\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let k = cfg.areas[0].kernel.as_ref().unwrap();
        assert_eq!(k.prob_at(300.0), cfg.areas[0].conn.amplitude);
    }

    #[test]
    fn atlas_validation_rejects_bad_shapes() {
        let base = || {
            let mut c = SimConfig::test_small();
            c.areas = vec![
                AreaParams {
                    name: "a".into(),
                    grid: GridParams { neurons_per_column: 20, ..GridParams::square(4) },
                    conn: ConnParams::gaussian(),
                    kernel: None,
                    external: None,
                },
                AreaParams {
                    name: "b".into(),
                    grid: GridParams { neurons_per_column: 20, ..GridParams::square(4) },
                    conn: ConnParams::gaussian(),
                    kernel: None,
                    external: None,
                },
            ];
            c.projections = vec![ProjectionParams::new("a", "b")];
            c
        };
        assert!(base().validate().is_ok());
        let mut c = base();
        c.areas[1].name = "a".into();
        assert!(c.validate().unwrap_err().contains("duplicate"));
        let mut c = base();
        c.projections[0].target = "nope".into();
        assert!(c.validate().unwrap_err().contains("unknown area"));
        let mut c = base();
        c.projections[0].stride = (0, 1);
        assert!(c.validate().unwrap_err().contains("stride"));
        let mut c = base();
        c.projections[0].velocity_um_per_ms = 0.0;
        assert!(c.validate().unwrap_err().contains("velocity"));
        // NaN must not slip through (NaN delays would saturate to 0 µs)
        let mut c = base();
        c.projections[0].delay_base_ms = f64::NAN;
        assert!(c.validate().unwrap_err().contains("delay_base_ms"));
        let mut c = base();
        c.projections[0].weight_scale = f64::NAN;
        assert!(c.validate().unwrap_err().contains("weight_scale"));
        let mut c = base();
        c.ranks = 17; // > 16 columns of area a
        assert!(c.validate().unwrap_err().contains("area"));
        let mut c = base();
        c.areas.clear();
        assert!(c.validate().unwrap_err().contains("projections require"));
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = SimConfig::test_small();
        c.ranks = 10_000;
        assert!(c.validate().unwrap_err().contains("ranks"));
        let mut c = SimConfig::test_small();
        c.syn.delay_min_ms = 0.0;
        assert!(c.validate().is_err());
        let mut c = SimConfig::test_small();
        c.conn.cutoff = 0.0;
        assert!(c.validate().is_err());
        // delay slots are u16: a delay/dt ratio past 65535 must be
        // rejected up front, not silently clamped (shortened) at build
        let mut c = SimConfig::test_small();
        c.dt_ms = 0.0005;
        c.syn.delay_min_ms = 0.0005;
        c.syn.delay_max_ms = 40.0;
        assert!(c.validate().unwrap_err().contains("delay-slot"));
        let mut c = SimConfig::test_small();
        c.grid.nx = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn delay_slots_cover_max_delay() {
        let c = SimConfig::test_small();
        assert!(c.delay_slots() as f64 * c.dt_ms > c.syn.delay_max_ms);
    }

    #[test]
    fn bad_rule_and_solver_strings() {
        assert!(ConnRule::parse("banana").is_err());
        assert!(Solver::parse("gpu").is_err());
        assert_eq!(ConnRule::parse("exp").unwrap(), ConnRule::Exponential);
        assert_eq!(Solver::parse("xla").unwrap(), Solver::Xla);
    }
}
