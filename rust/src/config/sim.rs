//! Typed simulation configuration.
//!
//! Mirrors the paper's experimental setup (§III): a 2D grid of cortical
//! modules ("columns") of 1240 LIF+SFA neurons each (80% excitatory),
//! spaced at α = 100 µm, wired with one of two remote-connectivity rules:
//!
//! * Gaussian (shorter range):   p(r) = A·exp(−r²/2σ²), A=0.05, σ=100 µm
//! * Exponential (longer range): p(r) = A·exp(−r/λ),    A=0.03, λ=290 µm
//!
//! plus a flat 80% same-column connection probability and a 1/1000
//! cutoff on the remote rule, which yields the paper's 7×7 (Gaussian)
//! and 21×21 (exponential) projection stencils (see
//! `connectivity::rules` for how the cutoff interacts with in-column
//! neuron positions to produce exactly those stencil sizes).
//!
//! Every knob is overridable from a TOML file (see `configs/*.toml`) or
//! from CLI flags; presets reproduce the paper's configurations.

use std::sync::Arc;

use crate::config::toml::Doc;
use crate::connectivity::kernel::{self, ConnectivityKernel};

/// Remote-connectivity decay law (paper §III-B).
///
/// The two paper presets. The open extension point is the
/// [`ConnectivityKernel`] trait (`connectivity::kernel`): additional
/// profiles — registered by name or fully custom — ride in
/// [`SimConfig::kernel`] and take precedence over this enum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnRule {
    /// Shorter range: p(r) = A·exp(−r²/2σ²).
    Gaussian,
    /// Longer range: p(r) = A·exp(−r/λ).
    Exponential,
}

impl ConnRule {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "gaussian" | "gauss" => Ok(ConnRule::Gaussian),
            "exponential" | "exp" => Ok(ConnRule::Exponential),
            other => Err(format!("unknown connectivity rule '{other}' (gaussian|exponential)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ConnRule::Gaussian => "gaussian",
            ConnRule::Exponential => "exponential",
        }
    }
}

/// Synaptic-delay distribution (paper §II-B: exponential or uniform).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DelayDist {
    /// Exponential with the given mean, clamped to [min, max].
    Exponential { mean_ms: f64 },
    /// Uniform over [min, max].
    Uniform,
}

/// Which registered neuron model a population runs (the dynamics-side
/// counterpart of the connectivity-kernel registry; integrators live in
/// `neuron::model` and docs/MODELS.md spells out the contract).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// LIF with spike-frequency adaptation (paper eqs. 1–2): exact
    /// event-driven integration, the bit-identical reference.
    Lif,
    /// Izhikevich (dimensional 2007 form): quadratic membrane +
    /// recovery variable, time-driven on the fixed Euler sub-grid.
    Izhikevich,
    /// Adaptive exponential integrate-and-fire (Brette–Gerstner):
    /// exponential spike initiation + adaptation current, time-driven.
    Adex,
}

impl ModelKind {
    /// Every registered model, in registry order (`dpsnn models`).
    pub const ALL: [ModelKind; 3] = [ModelKind::Lif, ModelKind::Izhikevich, ModelKind::Adex];

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "lif" => Ok(ModelKind::Lif),
            "izhikevich" | "izh" => Ok(ModelKind::Izhikevich),
            "adex" => Ok(ModelKind::Adex),
            other => Err(format!("unknown neuron model '{other}' (lif|izhikevich|adex)")),
        }
    }

    #[must_use]
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Lif => "lif",
            ModelKind::Izhikevich => "izhikevich",
            ModelKind::Adex => "adex",
        }
    }

    /// The model's state-lane layout, in lane order (see
    /// `neuron::model` for the fixed lane positions).
    #[must_use]
    pub fn lane_names(self) -> &'static [&'static str] {
        match self {
            ModelKind::Lif => &["v", "c", "last_t", "refr_until"],
            ModelKind::Izhikevich => &["v", "u", "last_t"],
            ModelKind::Adex => &["v", "w", "last_t", "refr_until"],
        }
    }

    #[must_use]
    pub fn n_lanes(self) -> usize {
        self.lane_names().len()
    }

    /// Stable checkpoint wire tag (never reorder — serialized state
    /// depends on it; see docs/RELIABILITY.md).
    #[must_use]
    pub fn tag(self) -> u8 {
        match self {
            ModelKind::Lif => 0,
            ModelKind::Izhikevich => 1,
            ModelKind::Adex => 2,
        }
    }

    /// Inverse of [`tag`](Self::tag); `None` for tags written by a
    /// build with models this one does not know.
    #[must_use]
    pub fn from_tag(tag: u8) -> Option<Self> {
        match tag {
            0 => Some(ModelKind::Lif),
            1 => Some(ModelKind::Izhikevich),
            2 => Some(ModelKind::Adex),
            _ => None,
        }
    }

    /// Time-driven models fire intrinsically between events and are
    /// polled to every step boundary; event-driven LIF is visited only
    /// when input arrives.
    #[must_use]
    pub fn time_driven(self) -> bool {
        !matches!(self, ModelKind::Lif)
    }

    /// One-line registry description (`dpsnn models`).
    #[must_use]
    pub fn summary(self) -> &'static str {
        match self {
            ModelKind::Lif => {
                "LIF + spike-frequency adaptation — exact event-driven integration \
                 (paper eqs. 1-2); the bit-identical reference"
            }
            ModelKind::Izhikevich => {
                "Izhikevich quadratic + recovery (2007 dimensional form) — \
                 time-driven Euler sub-grid; bias-driven intrinsic firing"
            }
            ModelKind::Adex => {
                "adaptive exponential IF (Brette-Gerstner) — time-driven Euler \
                 sub-grid; exponential spike initiation + adaptation current"
            }
        }
    }
}

/// Shape of a per-neuron parameter distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistKind {
    /// Every neuron uses the population mean (no sampling).
    None,
    /// Gaussian around the mean with s.d. `width`.
    Gaussian,
    /// Lorentzian (Cauchy) around the mean with half-width `width` —
    /// the heavy-tailed heterogeneity of the mean-field exemplars.
    Lorentzian,
}

impl DistKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "" | "none" => Ok(DistKind::None),
            "gaussian" => Ok(DistKind::Gaussian),
            "lorentzian" | "cauchy" => Ok(DistKind::Lorentzian),
            other => Err(format!(
                "unknown parameter distribution '{other}' (none|gaussian|lorentzian)"
            )),
        }
    }

    #[must_use]
    pub fn name(self) -> &'static str {
        match self {
            DistKind::None => "none",
            DistKind::Gaussian => "gaussian",
            DistKind::Lorentzian => "lorentzian",
        }
    }
}

/// Per-neuron distribution of one scalar model parameter, sampled at
/// construction from the dedicated counter-PRNG stream keyed on the
/// neuron's *global* id — so the draw is a pure function of
/// `(seed, gid)` and decomposition-invariant across rank counts and
/// mappings. Samples are truncated by rejection to a symmetric window
/// around the mean (threshold: `(v_reset, 2·mean − v_reset)`, time
/// constants: `(0, 2·mean)`), falling back to the mean after a bounded
/// number of rejections.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParamDist {
    pub kind: DistKind,
    /// Scale of the draw: s.d. for Gaussian, half-width γ for
    /// Lorentzian. `0.0` degenerates to the mean exactly.
    pub width: f64,
}

impl ParamDist {
    /// No sampling: every neuron gets the population mean.
    pub const NONE: ParamDist = ParamDist { kind: DistKind::None, width: 0.0 };

    /// Sampling actually perturbs values (a `width = 0` distribution is
    /// normalized away so σ=0 configs stay bit-identical to unsampled).
    #[must_use]
    pub fn is_active(&self) -> bool {
        self.kind != DistKind::None && self.width > 0.0
    }
}

/// Izhikevich-specific constants (`izh_*` keys; used only when
/// `model = "izhikevich"`). Defaults follow the regular-spiking set of
/// the FRE-oscillation exemplar (C=100, k=0.7, a=0.03, b=−2, d=80).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IzhCfg {
    /// Membrane capacitance C [pF].
    pub cap: f64,
    /// Quadratic gain k.
    pub k: f64,
    /// Recovery rate a [1/ms].
    pub a: f64,
    /// Recovery coupling b.
    pub b: f64,
    /// Spike-triggered recovery increment d.
    pub d: f64,
    /// Spike cut-off v_peak [mV].
    pub v_peak_mv: f64,
}

impl Default for IzhCfg {
    fn default() -> Self {
        IzhCfg { cap: 100.0, k: 0.7, a: 0.03, b: -2.0, d: 80.0, v_peak_mv: 35.0 }
    }
}

/// AdEx-specific constants (`adex_*` keys; used only when
/// `model = "adex"`). Defaults are the Brette–Gerstner regular-spiking
/// set in gL-normalized mV units (a = a/gL, b = b/gL).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdexCfg {
    /// Slope factor ΔT [mV].
    pub delta_t_mv: f64,
    /// Adaptation time constant τw [ms].
    pub tau_w_ms: f64,
    /// Subthreshold adaptation coupling a/gL (dimensionless).
    pub a: f64,
    /// Spike-triggered adaptation increment b/gL [mV].
    pub b_mv: f64,
    /// Spike cut-off v_peak [mV].
    pub v_peak_mv: f64,
}

impl Default for AdexCfg {
    fn default() -> Self {
        AdexCfg { delta_t_mv: 2.0, tau_w_ms: 144.0, a: 0.133, b_mv: 2.68, v_peak_mv: 0.0 }
    }
}

/// Parameters of one neuron population. The shared scalars (τ, E, Vθ,
/// Vr, τarp, SFA) are the paper's LIF+SFA set (eq. 1–2); `model`
/// selects the integrator that consumes them (see [`ModelKind`] for the
/// per-model mapping), `izh`/`adex` carry the model-specific extras,
/// and `v_theta_dist`/`tau_m_dist` optionally spread Vθ/τm per neuron.
#[derive(Clone, Copy, Debug)]
pub struct NeuronParams {
    /// Membrane time constant τm [ms].
    pub tau_m_ms: f64,
    /// Fatigue decay time τc [ms] (SFA / AHP current).
    pub tau_c_ms: f64,
    /// Resting potential E [mV].
    pub e_rest_mv: f64,
    /// Spike threshold Vθ [mV].
    pub v_theta_mv: f64,
    /// Post-spike reset Vr [mV].
    pub v_reset_mv: f64,
    /// Absolute refractory period τarp [ms].
    pub tau_arp_ms: f64,
    /// SFA coupling g_c/C_m [mV per unit c per ms] (0 for inhibitory).
    pub g_c_over_cm: f64,
    /// Fatigue increment per emitted spike α_c.
    pub alpha_c: f64,
    /// Which registered integrator runs this population.
    pub model: ModelKind,
    /// Constant background drive I_bias of the time-driven models
    /// (Izhikevich: current units consistent with C·k; AdEx: mV).
    /// Ignored by LIF, whose drive is purely event-based.
    pub bias: f64,
    /// Izhikevich extras (`izh_*` keys).
    pub izh: IzhCfg,
    /// AdEx extras (`adex_*` keys).
    pub adex: AdexCfg,
    /// Per-neuron spread of the threshold Vθ (Izhikevich: v_t).
    pub v_theta_dist: ParamDist,
    /// Per-neuron spread of the membrane time constant τm.
    pub tau_m_dist: ParamDist,
}

impl NeuronParams {
    /// Excitatory defaults; SFA active.
    pub fn excitatory() -> Self {
        NeuronParams {
            tau_m_ms: 20.0,
            tau_c_ms: 300.0,
            e_rest_mv: -65.0,
            v_theta_mv: -50.0,
            v_reset_mv: -60.0,
            tau_arp_ms: 2.0,
            g_c_over_cm: 0.02,
            alpha_c: 1.0,
            model: ModelKind::Lif,
            bias: 0.0,
            izh: IzhCfg::default(),
            adex: AdexCfg::default(),
            v_theta_dist: ParamDist::NONE,
            tau_m_dist: ParamDist::NONE,
        }
    }

    /// Inhibitory: SFA disabled (paper: "For inhibitory neurons, the SFA
    /// term is set to zero"), faster membrane.
    pub fn inhibitory() -> Self {
        NeuronParams { g_c_over_cm: 0.0, alpha_c: 0.0, tau_m_ms: 10.0, ..Self::excitatory() }
    }

    /// Some configured per-neuron distribution actually perturbs values
    /// (σ=0 / `none` distributions are normalized away).
    #[must_use]
    pub fn has_active_dist(&self) -> bool {
        self.v_theta_dist.is_active() || self.tau_m_dist.is_active()
    }
}

/// Connectivity parameters (paper §III-B).
#[derive(Clone, Copy, Debug)]
pub struct ConnParams {
    pub rule: ConnRule,
    /// Peak remote probability A (0.05 gauss / 0.03 exp).
    pub amplitude: f64,
    /// σ [µm] for Gaussian.
    pub sigma_um: f64,
    /// λ [µm] for exponential.
    pub lambda_um: f64,
    /// Same-column connection probability (0.8 → ~990 local synapses).
    pub local_prob: f64,
    /// Remote-rule cutoff: modules whose *best-case* connection
    /// probability is below this are never targeted (1/1000).
    pub cutoff: f64,
    /// Inhibitory neurons project only inside their column (Fig. 2).
    pub inhibitory_local_only: bool,
}

impl ConnParams {
    pub fn gaussian() -> Self {
        ConnParams {
            rule: ConnRule::Gaussian,
            amplitude: 0.05,
            sigma_um: 100.0,
            lambda_um: 290.0,
            local_prob: 0.8,
            cutoff: 1e-3,
            inhibitory_local_only: true,
        }
    }

    pub fn exponential() -> Self {
        ConnParams { rule: ConnRule::Exponential, amplitude: 0.03, ..Self::gaussian() }
    }

    /// Remote connection probability at distance `r_um` (no cutoff).
    ///
    /// Evaluates the `rule` preset's kernel (stack-built, no dispatch
    /// cost). A custom [`SimConfig::kernel`] overrides this for the
    /// whole pipeline — query `SimConfig::kernel_dyn` when the config
    /// is in scope.
    #[inline]
    pub fn prob_at(&self, r_um: f64) -> f64 {
        match self.rule {
            ConnRule::Gaussian => kernel::Gaussian {
                amplitude: self.amplitude,
                sigma_um: self.sigma_um,
            }
            .prob_at(r_um),
            ConnRule::Exponential => kernel::Exponential {
                amplitude: self.amplitude,
                lambda_um: self.lambda_um,
            }
            .prob_at(r_um),
        }
    }
}

/// Synaptic efficacy/delay parameters per projection class.
#[derive(Clone, Copy, Debug)]
pub struct SynParams {
    /// Excitatory efficacy mean [mV] (instantaneous ΔV on arrival).
    pub j_exc_mv: f64,
    /// Inhibitory efficacy mean [mV] (negative).
    pub j_inh_mv: f64,
    /// Relative s.d. of efficacies (gaussian draw, paper §II-B).
    pub j_rel_sd: f64,
    /// External (Poisson) efficacy [mV].
    pub j_ext_mv: f64,
    /// Delay distribution.
    pub delay_dist: DelayDist,
    /// Delay bounds [ms]; also the delay-queue horizon.
    pub delay_min_ms: f64,
    pub delay_max_ms: f64,
}

impl Default for SynParams {
    fn default() -> Self {
        SynParams {
            j_exc_mv: 0.12,
            j_inh_mv: -1.30,
            j_rel_sd: 0.25,
            j_ext_mv: 0.45,
            delay_dist: DelayDist::Exponential { mean_ms: 5.0 },
            delay_min_ms: 1.0,
            delay_max_ms: 40.0,
        }
    }
}

/// External (thalamo-cortical) stimulus: per-neuron Poisson bundle.
#[derive(Clone, Copy, Debug)]
pub struct ExternalParams {
    /// Number of external synapses afferent to each neuron. Table I's
    /// "total equivalent" minus recurrent synapses ⇒ ~420 per neuron.
    pub synapses_per_neuron: u32,
    /// Mean firing rate of each external synapse [Hz].
    pub rate_hz: f64,
}

impl Default for ExternalParams {
    fn default() -> Self {
        ExternalParams { synapses_per_neuron: 420, rate_hz: 3.0 }
    }
}

/// Per-area override of the external drive. Each field overrides the
/// global [`ExternalParams`] only when set: unspecified fields resolve
/// against the **live** global drive every time stimuli are (re)built,
/// so a half-specified area keeps following `Network::set_external`
/// sweeps for its unspecified half. (The PR-4 representation snapshotted
/// the global value at load time, which silently detached such areas
/// from every later sweep.)
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExternalOverride {
    pub synapses_per_neuron: Option<u32>,
    pub rate_hz: Option<f64>,
}

impl ExternalOverride {
    /// No override: the area follows the global drive entirely.
    pub fn none() -> Self {
        Self::default()
    }

    /// Fully specified: the area is detached from global sweeps (this
    /// is what a `Network::set_area_external` sweep installs).
    pub fn full(ext: ExternalParams) -> Self {
        ExternalOverride {
            synapses_per_neuron: Some(ext.synapses_per_neuron),
            rate_hz: Some(ext.rate_hz),
        }
    }

    pub fn is_none(&self) -> bool {
        self.synapses_per_neuron.is_none() && self.rate_hz.is_none()
    }

    /// Both fields overridden ⇒ global sweeps cannot affect this area.
    pub fn is_full(&self) -> bool {
        self.synapses_per_neuron.is_some() && self.rate_hz.is_some()
    }

    /// The effective drive against the (current) global default.
    pub fn resolve(&self, global: &ExternalParams) -> ExternalParams {
        ExternalParams {
            synapses_per_neuron: self
                .synapses_per_neuron
                .unwrap_or(global.synapses_per_neuron),
            rate_hz: self.rate_hz.unwrap_or(global.rate_hz),
        }
    }
}

/// Grid/network geometry (paper §III-B, Table I).
#[derive(Clone, Copy, Debug)]
pub struct GridParams {
    /// Columns along x.
    pub nx: u32,
    /// Columns along y.
    pub ny: u32,
    /// Inter-column spacing α [µm].
    pub spacing_um: f64,
    /// Neurons per column (1240).
    pub neurons_per_column: u32,
    /// Excitatory fraction (0.8).
    pub exc_fraction: f64,
}

impl GridParams {
    pub fn square(side: u32) -> Self {
        GridParams {
            nx: side,
            ny: side,
            spacing_um: 100.0,
            neurons_per_column: 1240,
            exc_fraction: 0.8,
        }
    }

    pub fn columns(&self) -> u64 {
        self.nx as u64 * self.ny as u64
    }

    pub fn neurons(&self) -> u64 {
        self.columns() * self.neurons_per_column as u64
    }

    // the cast is guarded by the explicit clamp below
    #[allow(clippy::cast_possible_truncation, clippy::cast_sign_loss)]
    pub fn exc_per_column(&self) -> u32 {
        // `validate` bounds exc_fraction to [0, 1], so the rounded product
        // can never exceed neurons_per_column; clamp anyway so even an
        // unvalidated config cannot truncate through the f64 round-trip
        // (and `inh_per_column`'s subtraction cannot underflow).
        let exc = (f64::from(self.neurons_per_column) * self.exc_fraction).round();
        if exc <= 0.0 {
            0
        } else if exc >= f64::from(self.neurons_per_column) {
            self.neurons_per_column
        } else {
            // lint: allow(lossy-cast, "clamped to [0, neurons_per_column] just above")
            exc as u32
        }
    }

    pub fn inh_per_column(&self) -> u32 {
        self.neurons_per_column - self.exc_per_column()
    }
}

/// One named area of a multi-area atlas configuration: its own grid,
/// intra-areal connectivity, optional external-drive override and
/// optional neuron-model overrides.
///
/// Synaptic efficacies/delays ([`SynParams`]) stay global; the neuron
/// model ([`NeuronParams`]) is per-area since PR 5 — heterogeneous
/// compositions (e.g. a strongly-adapting slow-wave area against an
/// awake-like area, arXiv:1902.08410) override `exc`/`inh` per area and
/// inherit everything they leave `None`.
#[derive(Clone, Debug)]
pub struct AreaParams {
    pub name: String,
    pub grid: GridParams,
    /// Intra-areal connectivity (local probability + remote kernel).
    pub conn: ConnParams,
    /// Custom intra-areal kernel; overrides `conn.rule` (same contract
    /// as [`SimConfig::kernel`]).
    pub kernel: Option<Arc<dyn ConnectivityKernel>>,
    /// Per-area external-drive override, resolved field-by-field
    /// against the **live** global [`SimConfig::external`] whenever
    /// stimuli are (re)built — see [`ExternalOverride`].
    pub external: ExternalOverride,
    /// Per-area excitatory neuron model (`None` → [`SimConfig::exc`]).
    pub exc: Option<NeuronParams>,
    /// Per-area inhibitory neuron model (`None` → [`SimConfig::inh`]).
    pub inh: Option<NeuronParams>,
}

impl AreaParams {
    /// An area with the given grid, paper-Gaussian intra-areal
    /// connectivity and everything else inherited from the globals.
    pub fn new(name: &str, grid: GridParams) -> Self {
        AreaParams {
            name: name.to_string(),
            grid,
            conn: ConnParams::gaussian(),
            kernel: None,
            external: ExternalOverride::none(),
            exc: None,
            inh: None,
        }
    }

    pub fn conn(mut self, conn: ConnParams) -> Self {
        self.conn = conn;
        self
    }

    pub fn kernel(mut self, kernel: Arc<dyn ConnectivityKernel>) -> Self {
        self.kernel = Some(kernel);
        self
    }

    /// Fully-specified external drive (detached from global sweeps).
    pub fn external(mut self, synapses_per_neuron: u32, rate_hz: f64) -> Self {
        self.external = ExternalOverride::full(ExternalParams { synapses_per_neuron, rate_hz });
        self
    }

    /// Rate-only override: the synapse count keeps following the global
    /// drive (including later `set_external` sweeps).
    pub fn external_rate(mut self, rate_hz: f64) -> Self {
        self.external.rate_hz = Some(rate_hz);
        self
    }

    /// Synapse-count-only override: the rate keeps following the global
    /// drive (including later `set_external` sweeps).
    pub fn external_synapses(mut self, synapses_per_neuron: u32) -> Self {
        self.external.synapses_per_neuron = Some(synapses_per_neuron);
        self
    }

    /// Override the excitatory neuron model of this area.
    pub fn exc_model(mut self, np: NeuronParams) -> Self {
        self.exc = Some(np);
        self
    }

    /// Override the inhibitory neuron model of this area.
    pub fn inh_model(mut self, np: NeuronParams) -> Self {
        self.inh = Some(np);
        self
    }
}

/// Rational per-axis topographic stride of a projection: source column
/// coordinate `c` maps to `c · up / down` (integer division last).
/// `down > 1` downsamples onto a smaller target grid (the PR-4 integer
/// stride); `up > 1` upsamples into a **larger** one, so feedback into
/// a bigger area lands topographically instead of leaning on kernel
/// spread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Stride {
    pub up: u32,
    pub down: u32,
}

impl Stride {
    /// Identity mapping (1:1).
    pub const ONE: Stride = Stride { up: 1, down: 1 };

    /// Downsampling stride `1:down` (PR-4 semantics).
    pub fn downsample(down: u32) -> Self {
        Stride { up: 1, down }
    }

    /// Upsampling stride `up:1`.
    pub fn upsample(up: u32) -> Self {
        Stride { up, down: 1 }
    }

    /// Map a source coordinate into the target frame (offset excluded).
    #[inline]
    pub fn map(&self, c: u32) -> i64 {
        (c as i64 * self.up as i64) / self.down as i64
    }
}

/// A typed inter-areal projection: source area → target area.
///
/// Source columns map **topographically** into the target area's column
/// grid — `mapped = offset + source_coords · up / down` per axis (see
/// [`Stride`]; integer `1:down` strides downsample, `up:1` strides
/// upsample into a larger area) — and the
/// projection then spreads **laterally** around the mapped column with
/// a [`ConnectivityKernel`] evaluated in the target area's own frame
/// (the source neuron's in-column jitter rides along, scaled to the
/// target spacing). Transmission delays follow a constant-plus-distance
/// model: `delay = delay_base_ms + r / velocity_um_per_ms`, clamped to
/// the global `[delay_min_ms, delay_max_ms]` window.
#[derive(Clone, Debug)]
pub struct ProjectionParams {
    /// Source area name.
    pub source: String,
    /// Target area name.
    pub target: String,
    /// Lateral-spread kernel parameters (amplitude/σ/λ/cutoff; the
    /// `local_prob` and `inhibitory_local_only` fields are unused here).
    pub conn: ConnParams,
    /// Custom lateral-spread kernel; overrides `conn.rule`.
    pub kernel: Option<Arc<dyn ConnectivityKernel>>,
    /// Topographic column-mapping offset (target columns).
    pub offset: (i32, i32),
    /// Rational topographic stride per axis: source column (cx, cy)
    /// maps to target column (offset + (cx·up/down, cy·up/down)).
    pub stride: (Stride, Stride),
    /// Only excitatory source neurons project (the long-range cortical
    /// default; Fig. 2's inhibitory-local rule extended across areas).
    pub excitatory_only: bool,
    /// Constant part of the inter-areal delay [ms] (the long-range
    /// tract); clamped into the global delay window.
    pub delay_base_ms: f64,
    /// Conduction velocity of the lateral-spread distance term
    /// [µm/ms]; 1000 µm/ms = 1 m/s.
    pub velocity_um_per_ms: f64,
    /// Multiplier on the drawn synaptic efficacies (> 0): inter-areal
    /// synapses are routinely modeled stronger (or weaker) than the
    /// local plexus without touching the global `SynParams`.
    pub weight_scale: f64,
    /// Per-synapse multiplicative efficacy spread (relative s.d., ≥ 0):
    /// each accepted synapse's weight is further scaled by
    /// `max(0, 1 + weight_jitter·z)` with a Gaussian `z` drawn from the
    /// same per-source counter-PRNG stream as the synapse itself, so
    /// the spread is decomposition-invariant. `0` (the default) draws
    /// nothing and is bit-identical to the pre-jitter wiring
    /// (arXiv:1512.05264 sweeps per-pathway efficacy this way).
    pub weight_jitter: f64,
}

impl ProjectionParams {
    /// A projection with the paper-Gaussian lateral spread, identity
    /// topography, excitatory-only sources and a 2 ms tract delay.
    pub fn new(source: &str, target: &str) -> Self {
        ProjectionParams {
            source: source.to_string(),
            target: target.to_string(),
            conn: ConnParams::gaussian(),
            kernel: None,
            offset: (0, 0),
            stride: (Stride::ONE, Stride::ONE),
            excitatory_only: true,
            delay_base_ms: 2.0,
            velocity_um_per_ms: 1000.0,
            weight_scale: 1.0,
            weight_jitter: 0.0,
        }
    }

    pub fn weight_scale(mut self, scale: f64) -> Self {
        self.weight_scale = scale;
        self
    }

    pub fn weight_jitter(mut self, jitter: f64) -> Self {
        self.weight_jitter = jitter;
        self
    }

    pub fn offset(mut self, dx: i32, dy: i32) -> Self {
        self.offset = (dx, dy);
        self
    }

    /// Downsampling stride (`1:s` per axis — PR-4 semantics kept).
    pub fn stride(mut self, sx: u32, sy: u32) -> Self {
        self.stride = (Stride::downsample(sx), Stride::downsample(sy));
        self
    }

    /// Upsampling stride (`u:1` per axis): feedback into a larger area
    /// lands topographically at `offset + coords · u`.
    pub fn upsample(mut self, ux: u32, uy: u32) -> Self {
        self.stride = (Stride::upsample(ux), Stride::upsample(uy));
        self
    }

    /// Fully rational per-axis stride (`mapped = offset + c·up/down`).
    pub fn stride_rational(mut self, x: Stride, y: Stride) -> Self {
        self.stride = (x, y);
        self
    }

    pub fn conn(mut self, conn: ConnParams) -> Self {
        self.conn = conn;
        self
    }

    pub fn kernel(mut self, kernel: Arc<dyn ConnectivityKernel>) -> Self {
        self.kernel = Some(kernel);
        self
    }

    pub fn excitatory_only(mut self, on: bool) -> Self {
        self.excitatory_only = on;
        self
    }

    pub fn delay(mut self, base_ms: f64, velocity_um_per_ms: f64) -> Self {
        self.delay_base_ms = base_ms;
        self.velocity_um_per_ms = velocity_um_per_ms;
        self
    }

    /// The lateral-spread kernel: custom when set, else `conn.rule`.
    pub fn kernel_dyn(&self) -> Arc<dyn ConnectivityKernel> {
        match &self.kernel {
            Some(k) => Arc::clone(k),
            None => kernel::from_rule(&self.conn),
        }
    }
}

/// Which neuron integrator the engine uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// Exact event-driven integration in Rust (paper's approach).
    EventDriven,
    /// Batched per-timestep update through the AOT-compiled XLA artifact
    /// (L1 Pallas kernel lowered to HLO, executed via PJRT).
    Xla,
}

impl Solver {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "event" | "event-driven" => Ok(Solver::EventDriven),
            "xla" => Ok(Solver::Xla),
            other => Err(format!("unknown solver '{other}' (event|xla)")),
        }
    }
}

/// Which dynamics implementation integrates the neuron lanes each step.
///
/// All three consume the same structure-of-arrays state
/// (`engine::soa::NeuronStateSoA`); `Scalar` and `Soa` are
/// bit-identical by contract (test-enforced), `Batch` is the XLA/PJRT
/// f32 path behind its own parity tolerance (see docs/PERF.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DynamicsBackend {
    /// Reference path: per-neuron AoS `LifState` loads/stores around
    /// the exact event-driven integrator (the pre-SoA semantics).
    Scalar,
    /// Default: gather + advance over the SoA lanes with memoized
    /// exponentials — same fp operations in the same order as `Scalar`.
    Soa,
    /// Batched per-timestep update through the AOT-compiled XLA
    /// artifact. Selected implicitly by `solver = "xla"`.
    Batch,
}

impl DynamicsBackend {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "scalar" => Ok(DynamicsBackend::Scalar),
            "soa" => Ok(DynamicsBackend::Soa),
            "batch" => Ok(DynamicsBackend::Batch),
            other => Err(format!("unknown backend '{other}' (scalar|soa|batch)")),
        }
    }
}

/// Which rank transport carries the virtual-MPI collectives (see
/// `mpi::comm::Transport` and docs/TRANSPORT.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Ranks as threads over the in-process channel matrix — the
    /// reference backend, and the default.
    Channel,
    /// Ranks as forked worker processes over mmap'd shared-memory
    /// rings — the paper's processes-exchanging-messages shape.
    Shm,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "channel" => Ok(TransportKind::Channel),
            "shm" => Ok(TransportKind::Shm),
            other => Err(format!("unknown transport '{other}' (channel|shm)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Shm => "shm",
        }
    }
}

/// Top-level simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub grid: GridParams,
    pub conn: ConnParams,
    pub syn: SynParams,
    pub exc: NeuronParams,
    pub inh: NeuronParams,
    pub external: ExternalParams,
    /// Time-driven communication step [ms] (paper: 1 ms).
    pub dt_ms: f64,
    /// Simulated duration [ms].
    pub duration_ms: f64,
    /// Number of (virtual MPI) ranks.
    pub ranks: u32,
    /// Rank transport. `None` defers to the `DPSNN_TRANSPORT`
    /// environment variable (CI forces whole suites onto one backend
    /// that way), falling back to [`TransportKind::Channel`]; an
    /// explicit value always wins — which is what lets a cross-backend
    /// test compare both even under a forced environment.
    pub transport: Option<TransportKind>,
    /// Ranks per (virtual) node for the construction-phase hierarchical
    /// Alltoallv (paper §II-D: intra-node gather, inter-node exchange,
    /// intra-node scatter). 1 — the default — means flat exchange; the
    /// result is bit-identical either way (test-enforced).
    pub ranks_per_node: u32,
    /// Global RNG seed — network is a pure function of this (any ranks).
    pub seed: u64,
    /// STDP plasticity (paper: disabled for all scaling measurements).
    pub plasticity: bool,
    pub solver: Solver,
    /// CPU dynamics backend (`Soa` default; `Scalar` is the bit-exact
    /// reference). Ignored under `solver = Xla`, which forces `Batch` —
    /// see [`dynamics_backend`](Self::dynamics_backend).
    pub backend: DynamicsBackend,
    /// Custom connectivity kernel; overrides `conn.rule` everywhere
    /// (stencil, synapse generation, analytics) when set. `None` means
    /// "use the preset named by `conn.rule`".
    pub kernel: Option<Arc<dyn ConnectivityKernel>>,
    /// Multi-area atlas: the named areas, in order. **Empty means the
    /// legacy single-grid world** described by `grid`/`conn`/`kernel`
    /// (normalized to a one-area atlas by [`area_list`](Self::area_list)
    /// — the single-grid path and the one-area atlas are the same code
    /// path, bit for bit). When non-empty, `grid`/`conn`/`kernel` serve
    /// only as the defaults areas inherit.
    pub areas: Vec<AreaParams>,
    /// Inter-areal projections (require ≥ 1 named area… or 1: an area
    /// may project onto itself as a second long-range system).
    pub projections: Vec<ProjectionParams>,
}

impl SimConfig {
    /// Paper-preset: Gaussian connectivity on a `side`×`side` grid.
    pub fn gaussian(side: u32) -> Self {
        SimConfig {
            grid: GridParams::square(side),
            conn: ConnParams::gaussian(),
            syn: SynParams::default(),
            exc: NeuronParams::excitatory(),
            inh: NeuronParams::inhibitory(),
            external: ExternalParams::default(),
            dt_ms: 1.0,
            duration_ms: 1000.0,
            ranks: 1,
            transport: None,
            ranks_per_node: 1,
            seed: 42,
            plasticity: false,
            solver: Solver::EventDriven,
            backend: DynamicsBackend::Soa,
            kernel: None,
            areas: Vec::new(),
            projections: Vec::new(),
        }
    }

    /// Paper-preset: exponential connectivity on a `side`×`side` grid.
    pub fn exponential(side: u32) -> Self {
        SimConfig { conn: ConnParams::exponential(), ..Self::gaussian(side) }
    }

    /// A small configuration for tests: tiny grid, reduced columns.
    pub fn test_small() -> Self {
        let mut c = Self::gaussian(4);
        c.grid.neurons_per_column = 50;
        c.external.synapses_per_neuron = 20;
        c.duration_ms = 50.0;
        c
    }

    /// Number of delay slots of `dt_ms` needed by the delay queues.
    // `validate` bounds delay_max_ms/dt_ms to (0, u16::MAX], so the
    // float→int cast can neither truncate nor go negative
    #[allow(clippy::cast_possible_truncation, clippy::cast_sign_loss)]
    pub fn delay_slots(&self) -> usize {
        (self.syn.delay_max_ms / self.dt_ms).ceil() as usize + 1
    }

    /// The dynamics backend the engine actually runs: `solver = Xla`
    /// forces `Batch` (the XLA artifact *is* the batched backend),
    /// otherwise the configured CPU backend. [`validate`](Self::validate)
    /// rejects `backend = Batch` without the XLA solver, so the two
    /// knobs cannot disagree.
    #[must_use]
    pub fn dynamics_backend(&self) -> DynamicsBackend {
        if self.solver == Solver::Xla {
            DynamicsBackend::Batch
        } else {
            self.backend
        }
    }

    /// The rank transport the engine actually uses: the explicit
    /// config value when set, else the `DPSNN_TRANSPORT` environment
    /// variable ("channel"|"shm", unknown values ignored), else the
    /// channel backend. Resolved once at `Network::build`; the built
    /// network records the resolved choice, so a mid-run environment
    /// change cannot flip backends.
    #[must_use]
    pub fn effective_transport(&self) -> TransportKind {
        if let Some(t) = self.transport {
            return t;
        }
        match std::env::var("DPSNN_TRANSPORT") {
            Ok(v) => TransportKind::parse(&v).unwrap_or(TransportKind::Channel),
            Err(_) => TransportKind::Channel,
        }
    }

    /// The connectivity kernel driving construction: the custom kernel
    /// when set, else the preset named by `conn.rule`.
    pub fn kernel_dyn(&self) -> Arc<dyn ConnectivityKernel> {
        match &self.kernel {
            Some(k) => Arc::clone(k),
            None => kernel::from_rule(&self.conn),
        }
    }

    /// Name of the effective connectivity kernel.
    pub fn kernel_name(&self) -> String {
        match &self.kernel {
            Some(k) => k.name().to_string(),
            None => self.conn.rule.name().to_string(),
        }
    }

    /// The normalized area list: `areas` when configured, else the
    /// legacy single grid as a one-area atlas ("area0" with this
    /// config's `grid`/`conn`/`kernel` and the global external drive).
    /// Everything downstream of configuration — geometry, synapse
    /// generation, the engine — consumes this view, so the single-grid
    /// path *is* the one-area atlas path.
    pub fn area_list(&self) -> Vec<AreaParams> {
        if self.areas.is_empty() {
            vec![AreaParams {
                name: "area0".to_string(),
                grid: self.grid,
                conn: self.conn,
                kernel: self.kernel.clone(),
                external: ExternalOverride::none(),
                exc: None,
                inh: None,
            }]
        } else {
            self.areas.clone()
        }
    }

    /// The atlas geometry of [`area_list`](Self::area_list).
    pub fn atlas(&self) -> crate::geometry::Atlas {
        crate::geometry::Atlas::new(
            self.area_list().into_iter().map(|a| (a.name, a.grid)).collect(),
        )
    }

    /// Total neurons across the atlas (equals `grid.neurons()` for the
    /// legacy single-grid configuration).
    pub fn total_neurons(&self) -> u64 {
        if self.areas.is_empty() {
            self.grid.neurons()
        } else {
            self.areas.iter().map(|a| a.grid.neurons()).sum()
        }
    }

    /// Load from a parsed TOML document; missing keys keep preset values.
    pub fn from_doc(doc: &Doc) -> Result<Self, String> {
        let rule_name = doc.str_or("connectivity.rule", "gaussian")?;
        let mut cfg = match ConnRule::parse(&rule_name) {
            Ok(ConnRule::Gaussian) => Self::gaussian(24),
            Ok(ConnRule::Exponential) => Self::exponential(24),
            // registered non-enum kernel: resolved below, once the
            // numeric connectivity overrides have been applied
            Err(_) => Self::gaussian(24),
        };
        let g = &mut cfg.grid;
        let side_x = u32_key(doc, "network.side", "", g.nx)?;
        let side_y = u32_key(doc, "network.side", "", g.ny)?;
        g.nx = u32_key(doc, "network.nx", "", side_x)?;
        g.ny = u32_key(doc, "network.ny", "", side_y)?;
        g.spacing_um = doc.float_or("network.spacing_um", g.spacing_um)?;
        g.neurons_per_column =
            u32_key(doc, "network.neurons_per_column", "", g.neurons_per_column)?;
        g.exc_fraction = doc.float_or("network.exc_fraction", g.exc_fraction)?;

        let c = &mut cfg.conn;
        c.amplitude = doc.float_or("connectivity.amplitude", c.amplitude)?;
        c.sigma_um = doc.float_or("connectivity.sigma_um", c.sigma_um)?;
        c.lambda_um = doc.float_or("connectivity.lambda_um", c.lambda_um)?;
        c.local_prob = doc.float_or("connectivity.local_prob", c.local_prob)?;
        c.cutoff = doc.float_or("connectivity.cutoff", c.cutoff)?;
        c.inhibitory_local_only =
            doc.bool_or("connectivity.inhibitory_local_only", c.inhibitory_local_only)?;

        if ConnRule::parse(&rule_name).is_err() {
            cfg.kernel = Some(kernel::from_doc(&rule_name, doc, &cfg.conn)?);
        }

        let s = &mut cfg.syn;
        s.j_exc_mv = doc.float_or("synapse.j_exc_mv", s.j_exc_mv)?;
        s.j_inh_mv = doc.float_or("synapse.j_inh_mv", s.j_inh_mv)?;
        s.j_rel_sd = doc.float_or("synapse.j_rel_sd", s.j_rel_sd)?;
        s.j_ext_mv = doc.float_or("synapse.j_ext_mv", s.j_ext_mv)?;
        s.delay_min_ms = doc.float_or("synapse.delay_min_ms", s.delay_min_ms)?;
        s.delay_max_ms = doc.float_or("synapse.delay_max_ms", s.delay_max_ms)?;
        match doc.str_or("synapse.delay_dist", "exponential")?.as_str() {
            "uniform" => s.delay_dist = DelayDist::Uniform,
            "exponential" => {
                let mean = doc.float_or("synapse.delay_mean_ms", 5.0)?;
                s.delay_dist = DelayDist::Exponential { mean_ms: mean };
            }
            other => return Err(format!("unknown delay_dist '{other}'")),
        }

        // global `model` key: both populations at once (the common
        // case); the per-section `model` key below still overrides
        let global_model = doc.str_or("neuron.model", "")?;
        for (np, sect) in [(&mut cfg.exc, "neuron.exc"), (&mut cfg.inh, "neuron.inh")] {
            np.tau_m_ms = doc.float_or(&format!("{sect}.tau_m_ms"), np.tau_m_ms)?;
            np.tau_c_ms = doc.float_or(&format!("{sect}.tau_c_ms"), np.tau_c_ms)?;
            np.e_rest_mv = doc.float_or(&format!("{sect}.e_rest_mv"), np.e_rest_mv)?;
            np.v_theta_mv = doc.float_or(&format!("{sect}.v_theta_mv"), np.v_theta_mv)?;
            np.v_reset_mv = doc.float_or(&format!("{sect}.v_reset_mv"), np.v_reset_mv)?;
            np.tau_arp_ms = doc.float_or(&format!("{sect}.tau_arp_ms"), np.tau_arp_ms)?;
            np.g_c_over_cm = doc.float_or(&format!("{sect}.g_c_over_cm"), np.g_c_over_cm)?;
            np.alpha_c = doc.float_or(&format!("{sect}.alpha_c"), np.alpha_c)?;
            if !global_model.is_empty() {
                np.model = ModelKind::parse(&global_model)?;
            }
            let model = doc.str_or(&format!("{sect}.model"), "")?;
            if !model.is_empty() {
                np.model = ModelKind::parse(&model)?;
            }
            np.bias = doc.float_or(&format!("{sect}.bias"), np.bias)?;
            np.izh.cap = doc.float_or(&format!("{sect}.izh_cap"), np.izh.cap)?;
            np.izh.k = doc.float_or(&format!("{sect}.izh_k"), np.izh.k)?;
            np.izh.a = doc.float_or(&format!("{sect}.izh_a"), np.izh.a)?;
            np.izh.b = doc.float_or(&format!("{sect}.izh_b"), np.izh.b)?;
            np.izh.d = doc.float_or(&format!("{sect}.izh_d"), np.izh.d)?;
            np.izh.v_peak_mv = doc.float_or(&format!("{sect}.izh_v_peak_mv"), np.izh.v_peak_mv)?;
            np.adex.delta_t_mv =
                doc.float_or(&format!("{sect}.adex_delta_t_mv"), np.adex.delta_t_mv)?;
            np.adex.tau_w_ms = doc.float_or(&format!("{sect}.adex_tau_w_ms"), np.adex.tau_w_ms)?;
            np.adex.a = doc.float_or(&format!("{sect}.adex_a"), np.adex.a)?;
            np.adex.b_mv = doc.float_or(&format!("{sect}.adex_b_mv"), np.adex.b_mv)?;
            np.adex.v_peak_mv =
                doc.float_or(&format!("{sect}.adex_v_peak_mv"), np.adex.v_peak_mv)?;
            np.v_theta_dist = ParamDist {
                kind: DistKind::parse(&doc.str_or(
                    &format!("{sect}.v_theta_dist"),
                    np.v_theta_dist.kind.name(),
                )?)?,
                width: doc
                    .float_or(&format!("{sect}.v_theta_dist_width"), np.v_theta_dist.width)?,
            };
            np.tau_m_dist = ParamDist {
                kind: DistKind::parse(&doc.str_or(
                    &format!("{sect}.tau_m_dist"),
                    np.tau_m_dist.kind.name(),
                )?)?,
                width: doc.float_or(&format!("{sect}.tau_m_dist_width"), np.tau_m_dist.width)?,
            };
        }

        cfg.external.synapses_per_neuron = u32_key(
            doc,
            "external.synapses_per_neuron",
            "",
            cfg.external.synapses_per_neuron,
        )?;
        cfg.external.rate_hz = doc.float_or("external.rate_hz", cfg.external.rate_hz)?;

        cfg.dt_ms = doc.float_or("simulation.dt_ms", cfg.dt_ms)?;
        cfg.duration_ms = doc.float_or("simulation.duration_ms", cfg.duration_ms)?;
        cfg.ranks = u32_key(doc, "simulation.ranks", "", cfg.ranks)?;
        // preset default seeds all fit i64; saturate rather than wrap if
        // a future preset somehow does not
        let seed = doc.int_or("simulation.seed", i64::try_from(cfg.seed).unwrap_or(i64::MAX))?;
        cfg.seed = u64::try_from(seed).map_err(|_| {
            format!("config key 'simulation.seed' must be a non-negative integer, got {seed}")
        })?;
        cfg.plasticity = doc.bool_or("simulation.plasticity", cfg.plasticity)?;
        cfg.solver = Solver::parse(&doc.str_or("simulation.solver", "event")?)?;
        cfg.backend = DynamicsBackend::parse(&doc.str_or("simulation.backend", "soa")?)?;
        let transport = doc.str_or("simulation.transport", "")?;
        if !transport.is_empty() {
            cfg.transport = Some(TransportKind::parse(&transport)?);
        }
        cfg.ranks_per_node =
            u32_key(doc, "simulation.ranks_per_node", "", cfg.ranks_per_node)?;

        // -- multi-area atlas: [[area]] / [[projection]] blocks --------
        // Areas inherit the already-resolved global [network] and
        // [connectivity] values as their defaults; every key may be
        // overridden per block. A config without [[area]] stays the
        // legacy single grid (areas empty ⇒ one-area atlas).
        for (i, area) in doc.tables("area")?.iter().enumerate() {
            let name = area
                .str_or("name", "")?
                .trim()
                .to_string();
            if name.is_empty() {
                return Err(format!("[[area]] #{}: missing 'name'", i + 1));
            }
            let ctx = format!("[[area]] '{name}' ");
            let mut g = cfg.grid;
            let side_x = u32_key(area, "side", &ctx, g.nx)?;
            let side_y = u32_key(area, "side", &ctx, g.ny)?;
            g.nx = u32_key(area, "nx", &ctx, side_x)?;
            g.ny = u32_key(area, "ny", &ctx, side_y)?;
            g.spacing_um = area.float_or("spacing_um", g.spacing_um)?;
            g.neurons_per_column =
                u32_key(area, "neurons_per_column", &ctx, g.neurons_per_column)?;
            g.exc_fraction = area.float_or("exc_fraction", g.exc_fraction)?;
            let (conn, kern) = conn_from_sub(area, &cfg.conn, cfg.kernel.clone())?;
            // an override field exists only for the keys the block names
            // — the unspecified half keeps following the live global
            // drive through every later sweep (see ExternalOverride)
            let external = ExternalOverride {
                synapses_per_neuron: if area.get("external_synapses_per_neuron").is_some() {
                    Some(u32_key(area, "external_synapses_per_neuron", &ctx, 0)?)
                } else {
                    None
                },
                rate_hz: if area.get("external_rate_hz").is_some() {
                    Some(area.float("external_rate_hz")?)
                } else {
                    None
                },
            };
            let exc = neuron_from_sub(area, "exc", &cfg.exc)?;
            let inh = neuron_from_sub(area, "inh", &cfg.inh)?;
            cfg.areas.push(AreaParams { name, grid: g, conn, kernel: kern, external, exc, inh });
        }
        for (i, proj) in doc.tables("projection")?.iter().enumerate() {
            let source = proj.str_or("source", "")?;
            let target = proj.str_or("target", "")?;
            if source.is_empty() || target.is_empty() {
                return Err(format!("[[projection]] #{}: missing 'source'/'target'", i + 1));
            }
            let ctx = format!("[[projection]] '{source}'->'{target}' ");
            let d = ProjectionParams::new(&source, &target);
            let (conn, kern) = conn_from_sub(proj, &d.conn, None)?;
            cfg.projections.push(ProjectionParams {
                source,
                target,
                conn,
                kernel: kern,
                offset: (
                    i32_key(proj, "offset_x", &ctx, d.offset.0)?,
                    i32_key(proj, "offset_y", &ctx, d.offset.1)?,
                ),
                stride: (
                    Stride {
                        up: u32_key(proj, "stride_up_x", &ctx, d.stride.0.up)?,
                        down: u32_key(proj, "stride_x", &ctx, d.stride.0.down)?,
                    },
                    Stride {
                        up: u32_key(proj, "stride_up_y", &ctx, d.stride.1.up)?,
                        down: u32_key(proj, "stride_y", &ctx, d.stride.1.down)?,
                    },
                ),
                excitatory_only: proj.bool_or("excitatory_only", d.excitatory_only)?,
                delay_base_ms: proj.float_or("delay_base_ms", d.delay_base_ms)?,
                velocity_um_per_ms: proj
                    .float_or("velocity_um_per_ms", d.velocity_um_per_ms)?,
                weight_scale: proj.float_or("weight_scale", d.weight_scale)?,
                weight_jitter: proj.float_or("weight_jitter", d.weight_jitter)?,
            });
        }

        cfg.validate()?;
        Ok(cfg)
    }

    fn validate_grid(g: &GridParams, what: &str) -> Result<(), String> {
        if g.nx == 0 || g.ny == 0 {
            return Err(format!("{what}: grid must be non-empty"));
        }
        if g.neurons_per_column == 0 {
            return Err(format!("{what}: neurons_per_column must be > 0"));
        }
        if !(0.0..=1.0).contains(&g.exc_fraction) {
            return Err(format!("{what}: exc_fraction must be in [0,1]"));
        }
        Ok(())
    }

    fn validate_neuron(np: &NeuronParams, what: &str) -> Result<(), String> {
        let tau_ok = |t: f64| t.is_finite() && t > 0.0;
        if !tau_ok(np.tau_m_ms) || !tau_ok(np.tau_c_ms) {
            return Err(format!("{what}: tau_m_ms/tau_c_ms must be finite and > 0"));
        }
        if !np.tau_arp_ms.is_finite() || np.tau_arp_ms < 0.0 {
            return Err(format!("{what}: tau_arp_ms must be finite and >= 0"));
        }
        if !np.v_theta_mv.is_finite()
            || !np.v_reset_mv.is_finite()
            || np.v_theta_mv <= np.v_reset_mv
        {
            return Err(format!(
                "{what}: v_theta_mv must be finite and exceed v_reset_mv (a reset at \
                 or above threshold would re-fire on every event)"
            ));
        }
        if !np.bias.is_finite() {
            return Err(format!("{what}: bias must be finite"));
        }
        match np.model {
            ModelKind::Lif => {}
            ModelKind::Izhikevich => {
                let i = &np.izh;
                if !(i.cap.is_finite() && i.cap > 0.0) || !(i.k.is_finite() && i.k > 0.0) {
                    return Err(format!("{what}: izh_cap/izh_k must be finite and > 0"));
                }
                if !(i.a.is_finite() && i.b.is_finite() && i.d.is_finite()) {
                    return Err(format!("{what}: izh_a/izh_b/izh_d must be finite"));
                }
                if !i.v_peak_mv.is_finite() || i.v_peak_mv <= np.v_theta_mv {
                    return Err(format!(
                        "{what}: izh_v_peak_mv must be finite and exceed v_theta_mv \
                         (the quadratic crosses v_t on its way to the peak)"
                    ));
                }
            }
            ModelKind::Adex => {
                let a = &np.adex;
                if !(a.delta_t_mv.is_finite() && a.delta_t_mv > 0.0)
                    || !(a.tau_w_ms.is_finite() && a.tau_w_ms > 0.0)
                {
                    return Err(format!(
                        "{what}: adex_delta_t_mv/adex_tau_w_ms must be finite and > 0"
                    ));
                }
                if !(a.a.is_finite() && a.b_mv.is_finite()) {
                    return Err(format!("{what}: adex_a/adex_b_mv must be finite"));
                }
                if !a.v_peak_mv.is_finite() || a.v_peak_mv <= np.v_reset_mv {
                    return Err(format!(
                        "{what}: adex_v_peak_mv must be finite and exceed v_reset_mv"
                    ));
                }
            }
        }
        for (dist, key) in [(&np.v_theta_dist, "v_theta_dist"), (&np.tau_m_dist, "tau_m_dist")]
        {
            if !dist.width.is_finite() || dist.width < 0.0 {
                return Err(format!("{what}: {key}_width must be finite and >= 0"));
            }
        }
        Ok(())
    }

    fn validate_conn(c: &ConnParams, what: &str) -> Result<(), String> {
        if !(0.0..=1.0).contains(&c.local_prob) {
            return Err(format!("{what}: local_prob must be in [0,1]"));
        }
        if c.amplitude <= 0.0 || c.amplitude > 1.0 {
            return Err(format!("{what}: connectivity amplitude must be in (0,1]"));
        }
        if c.cutoff <= 0.0 {
            return Err(format!("{what}: cutoff must be > 0"));
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        Self::validate_grid(&self.grid, "network")?;
        Self::validate_conn(&self.conn, "connectivity")?;
        Self::validate_neuron(&self.exc, "neuron.exc")?;
        Self::validate_neuron(&self.inh, "neuron.inh")?;
        if !self.external.rate_hz.is_finite() || self.external.rate_hz < 0.0 {
            return Err("external.rate_hz must be finite and >= 0".into());
        }
        // -- atlas-specific checks ------------------------------------
        for (i, a) in self.areas.iter().enumerate() {
            let what = format!("area '{}'", a.name);
            if a.name.is_empty() {
                return Err(format!("area #{}: empty name", i + 1));
            }
            if self.areas[..i].iter().any(|b| b.name == a.name) {
                return Err(format!("duplicate area name '{}'", a.name));
            }
            Self::validate_grid(&a.grid, &what)?;
            Self::validate_conn(&a.conn, &what)?;
            if let Some(np) = &a.exc {
                Self::validate_neuron(np, &format!("{what} exc model"))?;
            }
            if let Some(np) = &a.inh {
                Self::validate_neuron(np, &format!("{what} inh model"))?;
            }
            if let Some(r) = a.external.rate_hz {
                if !r.is_finite() || r < 0.0 {
                    return Err(format!(
                        "{what}: external_rate_hz must be finite and >= 0"
                    ));
                }
            }
            if self.ranks as u64 > a.grid.columns() {
                return Err(format!(
                    "ranks ({}) exceed columns ({}) of area '{}': every area is \
                     decomposed over all ranks",
                    self.ranks,
                    a.grid.columns(),
                    a.name
                ));
            }
        }
        // The XLA batch path accepts per-area neuron models as long as
        // every used parameter set shares the scalars the compiled
        // artifact treats as globals (E, θ, Vr, τarp): the SoA param_id
        // table carries per-population τ/g̃/α_c lanes, so only the
        // shared scalars remain a hard constraint (PR 8 lifted the old
        // blanket rejection of per-area models under `solver = xla`).
        if self.solver == Solver::Xla {
            let shared =
                |np: &NeuronParams| (np.e_rest_mv, np.v_theta_mv, np.v_reset_mv, np.tau_arp_ms);
            let want = shared(&self.exc);
            let check = |np: &NeuronParams, what: &str| -> Result<(), String> {
                // the compiled artifact implements exactly the LIF+SFA
                // step with population-mean constants: other registered
                // models and per-neuron sampling are rejected by name
                // here (no silent fallback to the CPU paths)
                if np.model != ModelKind::Lif {
                    return Err(format!(
                        "{what}: solver = \"xla\" supports only model = \"lif\" (got \
                         \"{}\"); run the time-driven models on the event-driven \
                         solver",
                        np.model.name()
                    ));
                }
                if np.has_active_dist() {
                    return Err(format!(
                        "{what}: solver = \"xla\" does not support per-neuron \
                         parameter distributions (v_theta_dist/tau_m_dist); use the \
                         event-driven solver"
                    ));
                }
                if shared(np) == want {
                    return Ok(());
                }
                Err(format!(
                    "{what}: the XLA batch solver assumes shared E/θ/Vr/τarp across \
                     populations (global exc: E={} θ={} Vr={} τarp={}); per-area \
                     τ/g̃/α_c overrides are supported, the shared scalars are not",
                    want.0, want.1, want.2, want.3
                ))
            };
            check(&self.exc, "neuron.exc")?;
            check(&self.inh, "neuron.inh")?;
            for a in &self.areas {
                if let Some(np) = &a.exc {
                    check(np, &format!("area '{}' exc model", a.name))?;
                }
                if let Some(np) = &a.inh {
                    check(np, &format!("area '{}' inh model", a.name))?;
                }
            }
        }
        if self.ranks_per_node == 0 {
            return Err("simulation.ranks_per_node must be >= 1".into());
        }
        if self.transport == Some(TransportKind::Shm) && self.solver == Solver::Xla {
            return Err(
                "transport = \"shm\" is incompatible with solver = \"xla\": the PJRT \
                 client does not survive fork(); run the XLA solver on the channel \
                 transport"
                    .into(),
            );
        }
        if self.backend == DynamicsBackend::Batch && self.solver != Solver::Xla {
            return Err(
                "backend = \"batch\" requires solver = \"xla\" (the batched backend IS \
                 the XLA artifact; use \"soa\" or \"scalar\" for the CPU paths)"
                    .into(),
            );
        }
        // the SoA state resolves neuron models through a u8 param_id
        // with 2 populations per area — the atlas caps at 128 areas
        if self.areas.len() > 128 {
            return Err(format!(
                "atlas has {} areas; the per-neuron param_id is a u8 over two \
                 populations per area, capping the atlas at 128 areas",
                self.areas.len()
            ));
        }
        if !self.projections.is_empty() && self.areas.is_empty() {
            return Err("projections require named [[area]] blocks".into());
        }
        for p in &self.projections {
            let what = format!("projection '{}'->'{}'", p.source, p.target);
            for name in [&p.source, &p.target] {
                if !self.areas.iter().any(|a| &a.name == name) {
                    return Err(format!("{what}: unknown area '{name}'"));
                }
            }
            Self::validate_conn(&p.conn, &what)?;
            for s in [p.stride.0, p.stride.1] {
                if s.up == 0 || s.down == 0 {
                    return Err(format!("{what}: stride up/down must be >= 1"));
                }
            }
            if !p.delay_base_ms.is_finite() || p.delay_base_ms < 0.0 {
                return Err(format!("{what}: delay_base_ms must be finite and >= 0"));
            }
            if p.velocity_um_per_ms.is_nan() || p.velocity_um_per_ms <= 0.0 {
                return Err(format!("{what}: velocity_um_per_ms must be > 0"));
            }
            if !p.weight_scale.is_finite() || p.weight_scale <= 0.0 {
                return Err(format!("{what}: weight_scale must be finite and > 0"));
            }
            if !p.weight_jitter.is_finite() || p.weight_jitter < 0.0 {
                return Err(format!("{what}: weight_jitter must be finite and >= 0"));
            }
        }
        // AER wire spikes and synapse endpoints carry gids as u32
        if self.total_neurons() > u32::MAX as u64 + 1 {
            return Err(format!(
                "total neurons ({}) exceed the u32 gid space of the AER wire format",
                self.total_neurons()
            ));
        }
        if self.dt_ms <= 0.0 || self.duration_ms < 0.0 {
            return Err("dt/duration must be positive".into());
        }
        if self.syn.delay_min_ms < self.dt_ms {
            return Err(format!(
                "delay_min_ms ({}) must be >= dt_ms ({}): a spike emitted in step t \
                 is delivered at t+delay, and the exchange happens once per dt",
                self.syn.delay_min_ms, self.dt_ms
            ));
        }
        if self.syn.delay_max_ms < self.syn.delay_min_ms {
            return Err("delay_max_ms < delay_min_ms".into());
        }
        if self.syn.delay_max_ms / self.dt_ms > u16::MAX as f64 {
            return Err(format!(
                "delay_max_ms / dt_ms = {:.0} exceeds the {}-step delay-slot range \
                 (delays are precomputed in whole dt-steps as u16): raise dt_ms or \
                 lower delay_max_ms",
                self.syn.delay_max_ms / self.dt_ms,
                u16::MAX
            ));
        }
        if self.ranks == 0 {
            return Err("ranks must be >= 1".into());
        }
        // per-area rank bounds are checked above; the legacy grid bound
        // applies only when the legacy grid is the world
        if self.areas.is_empty() && self.ranks as u64 > self.grid.columns() {
            return Err(format!(
                "ranks ({}) exceed columns ({}): the spatial mapping assigns whole \
                 columns to ranks",
                self.ranks,
                self.grid.columns()
            ));
        }
        Ok(())
    }
}

/// Sign- and range-checked integer lookup. TOML integers flow through
/// `i64`, and the old bare `as u32` casts silently wrapped negatives —
/// `nx = -1` became 4294967295 and sailed straight past `validate_grid`'s
/// `== 0` checks. `ctx` names the enclosing block (empty for global
/// tables) so the error points at the offending key.
fn u32_key(doc: &Doc, key: &str, ctx: &str, default: u32) -> Result<u32, String> {
    let v = doc.int_or(key, i64::from(default))?;
    u32::try_from(v).map_err(|_| {
        format!(
            "{ctx}config key '{key}' must be a non-negative integer \
             (at most {}), got {v}",
            u32::MAX
        )
    })
}

/// [`u32_key`], but for signed 32-bit keys (topographic offsets): the
/// sign is legal, silent `as i32` truncation of out-of-range values is
/// not.
fn i32_key(doc: &Doc, key: &str, ctx: &str, default: i32) -> Result<i32, String> {
    let v = doc.int_or(key, i64::from(default))?;
    i32::try_from(v).map_err(|_| {
        format!("{ctx}config key '{key}' must fit a signed 32-bit integer, got {v}")
    })
}

/// Per-area neuron-model override from the `{prefix}_*` keys of one
/// `[[area]]` block (e.g. `exc_g_c_over_cm = 0.08`); `None` when the
/// block names no key of that population. Unset fields inherit `base`
/// (the already-resolved global model) at load time — neuron models,
/// unlike the external drive, have no mid-run sweep, so load-time
/// resolution is exact.
fn neuron_from_sub(
    sub: &Doc,
    prefix: &str,
    base: &NeuronParams,
) -> Result<Option<NeuronParams>, String> {
    const KEYS: [&str; 25] = [
        "tau_m_ms",
        "tau_c_ms",
        "e_rest_mv",
        "v_theta_mv",
        "v_reset_mv",
        "tau_arp_ms",
        "g_c_over_cm",
        "alpha_c",
        "model",
        "bias",
        "izh_cap",
        "izh_k",
        "izh_a",
        "izh_b",
        "izh_d",
        "izh_v_peak_mv",
        "adex_delta_t_mv",
        "adex_tau_w_ms",
        "adex_a",
        "adex_b_mv",
        "adex_v_peak_mv",
        "v_theta_dist",
        "v_theta_dist_width",
        "tau_m_dist",
        "tau_m_dist_width",
    ];
    if !KEYS.iter().any(|k| sub.get(&format!("{prefix}_{k}")).is_some()) {
        return Ok(None);
    }
    let mut np = *base;
    np.tau_m_ms = sub.float_or(&format!("{prefix}_tau_m_ms"), np.tau_m_ms)?;
    np.tau_c_ms = sub.float_or(&format!("{prefix}_tau_c_ms"), np.tau_c_ms)?;
    np.e_rest_mv = sub.float_or(&format!("{prefix}_e_rest_mv"), np.e_rest_mv)?;
    np.v_theta_mv = sub.float_or(&format!("{prefix}_v_theta_mv"), np.v_theta_mv)?;
    np.v_reset_mv = sub.float_or(&format!("{prefix}_v_reset_mv"), np.v_reset_mv)?;
    np.tau_arp_ms = sub.float_or(&format!("{prefix}_tau_arp_ms"), np.tau_arp_ms)?;
    np.g_c_over_cm = sub.float_or(&format!("{prefix}_g_c_over_cm"), np.g_c_over_cm)?;
    np.alpha_c = sub.float_or(&format!("{prefix}_alpha_c"), np.alpha_c)?;
    let model = sub.str_or(&format!("{prefix}_model"), np.model.name())?;
    np.model = ModelKind::parse(&model)?;
    np.bias = sub.float_or(&format!("{prefix}_bias"), np.bias)?;
    np.izh.cap = sub.float_or(&format!("{prefix}_izh_cap"), np.izh.cap)?;
    np.izh.k = sub.float_or(&format!("{prefix}_izh_k"), np.izh.k)?;
    np.izh.a = sub.float_or(&format!("{prefix}_izh_a"), np.izh.a)?;
    np.izh.b = sub.float_or(&format!("{prefix}_izh_b"), np.izh.b)?;
    np.izh.d = sub.float_or(&format!("{prefix}_izh_d"), np.izh.d)?;
    np.izh.v_peak_mv = sub.float_or(&format!("{prefix}_izh_v_peak_mv"), np.izh.v_peak_mv)?;
    np.adex.delta_t_mv =
        sub.float_or(&format!("{prefix}_adex_delta_t_mv"), np.adex.delta_t_mv)?;
    np.adex.tau_w_ms = sub.float_or(&format!("{prefix}_adex_tau_w_ms"), np.adex.tau_w_ms)?;
    np.adex.a = sub.float_or(&format!("{prefix}_adex_a"), np.adex.a)?;
    np.adex.b_mv = sub.float_or(&format!("{prefix}_adex_b_mv"), np.adex.b_mv)?;
    np.adex.v_peak_mv = sub.float_or(&format!("{prefix}_adex_v_peak_mv"), np.adex.v_peak_mv)?;
    let vdist = sub.str_or(&format!("{prefix}_v_theta_dist"), np.v_theta_dist.kind.name())?;
    np.v_theta_dist.kind = DistKind::parse(&vdist)?;
    np.v_theta_dist.width =
        sub.float_or(&format!("{prefix}_v_theta_dist_width"), np.v_theta_dist.width)?;
    let tdist = sub.str_or(&format!("{prefix}_tau_m_dist"), np.tau_m_dist.kind.name())?;
    np.tau_m_dist.kind = DistKind::parse(&tdist)?;
    np.tau_m_dist.width =
        sub.float_or(&format!("{prefix}_tau_m_dist_width"), np.tau_m_dist.width)?;
    Ok(Some(np))
}

/// Resolve connectivity parameters from one `[[area]]`/`[[projection]]`
/// block: numeric keys override `base`, and `rule` selects either a
/// preset (enum) or a registered kernel name resolved against the
/// overridden numbers. `base_kernel` is the inherited custom kernel
/// (kept when the block names no rule of its own).
fn conn_from_sub(
    sub: &Doc,
    base: &ConnParams,
    base_kernel: Option<Arc<dyn ConnectivityKernel>>,
) -> Result<(ConnParams, Option<Arc<dyn ConnectivityKernel>>), String> {
    let mut conn = *base;
    conn.amplitude = sub.float_or("amplitude", conn.amplitude)?;
    conn.sigma_um = sub.float_or("sigma_um", conn.sigma_um)?;
    conn.lambda_um = sub.float_or("lambda_um", conn.lambda_um)?;
    conn.local_prob = sub.float_or("local_prob", conn.local_prob)?;
    conn.cutoff = sub.float_or("cutoff", conn.cutoff)?;
    conn.inhibitory_local_only =
        sub.bool_or("inhibitory_local_only", conn.inhibitory_local_only)?;
    match sub.get("rule") {
        None => {
            // Inherited registered kernel + per-block numeric overrides:
            // re-resolve the kernel by name against the overridden
            // numbers, otherwise the block's sigma/lambda/amplitude edits
            // would silently apply only to validation, not to the wiring.
            // (Kernel-specific extras like lambda_near_um are registry
            // defaults after re-resolution; set `rule` in the block to
            // control them per area.)
            let numeric_override = ["amplitude", "sigma_um", "lambda_um"]
                .iter()
                .any(|k| sub.get(k).is_some());
            let kernel = match base_kernel {
                Some(k) if numeric_override => {
                    Some(kernel::builtin(k.name(), &conn).unwrap_or(k))
                }
                other => other,
            };
            Ok((conn, kernel))
        }
        Some(_) => {
            let rule_name = sub.str("rule")?;
            match ConnRule::parse(&rule_name) {
                Ok(rule) => {
                    conn.rule = rule;
                    Ok((conn, None))
                }
                Err(_) => {
                    let k = kernel::resolve(&rule_name, &conn)?;
                    Ok((conn, Some(k)))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;

    #[test]
    fn presets_match_paper_parameters() {
        let g = SimConfig::gaussian(24);
        assert_eq!(g.conn.amplitude, 0.05);
        assert_eq!(g.conn.sigma_um, 100.0);
        assert_eq!(g.grid.neurons_per_column, 1240);
        assert_eq!(g.grid.exc_per_column(), 992);
        assert_eq!(g.grid.inh_per_column(), 248);
        assert_eq!(g.grid.columns(), 576);
        assert_eq!(g.grid.neurons(), 714_240);
        let e = SimConfig::exponential(48);
        assert_eq!(e.conn.amplitude, 0.03);
        assert_eq!(e.conn.lambda_um, 290.0);
        assert_eq!(e.grid.neurons(), 2_856_960); // 2.9 M in Table I
    }

    #[test]
    fn probability_laws() {
        let g = ConnParams::gaussian();
        assert!((g.prob_at(0.0) - 0.05).abs() < 1e-12);
        assert!((g.prob_at(100.0) - 0.05 * (-0.5f64).exp()).abs() < 1e-12);
        let e = ConnParams::exponential();
        assert!((e.prob_at(0.0) - 0.03).abs() < 1e-12);
        assert!((e.prob_at(290.0) - 0.03 * (-1.0f64).exp()).abs() < 1e-12);
        // exponential is the longer-range law
        assert!(e.prob_at(500.0) > g.prob_at(500.0));
    }

    #[test]
    fn from_doc_roundtrip_and_overrides() {
        let doc = toml::parse(
            r#"
[network]
side = 8
neurons_per_column = 100

[connectivity]
rule = "exponential"
lambda_um = 240.0

[simulation]
ranks = 4
duration_ms = 123.0
solver = "event"
"#,
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.grid.nx, 8);
        assert_eq!(cfg.grid.neurons_per_column, 100);
        assert_eq!(cfg.conn.rule, ConnRule::Exponential);
        assert_eq!(cfg.conn.lambda_um, 240.0);
        assert_eq!(cfg.conn.amplitude, 0.03); // preset kept
        assert_eq!(cfg.ranks, 4);
        assert_eq!(cfg.duration_ms, 123.0);
    }

    #[test]
    fn from_doc_resolves_registered_kernels() {
        let doc = toml::parse(
            r#"
[connectivity]
rule = "doubly-exponential"
lambda_near_um = 120.0
lambda_far_um = 600.0
mix = 0.6
"#,
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let k = cfg.kernel_dyn();
        assert_eq!(k.name(), "doubly-exponential");
        assert_eq!(cfg.kernel_name(), "doubly-exponential");
        // p(0) = A (mix + 1 − mix) = amplitude
        assert!((k.prob_at(0.0) - cfg.conn.amplitude).abs() < 1e-12);

        let doc = toml::parse("[connectivity]\nrule = \"flat-disc\"\ndisc_radius_um = 150.0\n")
            .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.kernel_dyn().name(), "flat-disc");
        assert_eq!(cfg.kernel_dyn().prob_at(150.0), cfg.conn.amplitude);
        assert_eq!(cfg.kernel_dyn().prob_at(151.0), 0.0);

        let doc = toml::parse("[connectivity]\nrule = \"banana\"\n").unwrap();
        let err = SimConfig::from_doc(&doc).unwrap_err();
        assert!(err.contains("banana") && err.contains("flat-disc"), "{err}");

        // enum presets keep kernel = None (legacy path untouched)
        let cfg = SimConfig::gaussian(8);
        assert!(cfg.kernel.is_none());
        assert_eq!(cfg.kernel_dyn().name(), "gaussian");
    }

    #[test]
    fn area_and_projection_blocks_parse_with_inheritance() {
        let doc = toml::parse(
            r#"
[network]
side = 6
neurons_per_column = 50

[connectivity]
rule = "gaussian"
amplitude = 0.04

[external]
synapses_per_neuron = 80
rate_hz = 10.0

[[area]]
name = "v1"

[[area]]
name = "v2"
side = 4
rule = "exponential"
external_rate_hz = 0.0

[[projection]]
source = "v1"
target = "v2"
rule = "exponential"
lambda_um = 200.0
offset_x = -1
stride_x = 2
excitatory_only = false
delay_base_ms = 3.0
velocity_um_per_ms = 500.0

[simulation]
ranks = 2
"#,
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.areas.len(), 2);
        // v1 inherits the global grid + connectivity
        assert_eq!(cfg.areas[0].name, "v1");
        assert_eq!(cfg.areas[0].grid.nx, 6);
        assert_eq!(cfg.areas[0].grid.neurons_per_column, 50);
        assert_eq!(cfg.areas[0].conn.rule, ConnRule::Gaussian);
        assert_eq!(cfg.areas[0].conn.amplitude, 0.04);
        assert!(cfg.areas[0].external.is_none());
        // v2 overrides grid side, rule and (half of) the external drive
        assert_eq!(cfg.areas[1].grid.nx, 4);
        assert_eq!(cfg.areas[1].conn.rule, ConnRule::Exponential);
        let ext = cfg.areas[1].external;
        assert_eq!(ext.rate_hz, Some(0.0));
        // the unspecified half is NOT snapshotted at load time: it
        // resolves against the live global drive at stimulus build
        assert_eq!(ext.synapses_per_neuron, None);
        assert!(!ext.is_full());
        assert_eq!(ext.resolve(&cfg.external).synapses_per_neuron, 80);
        assert_eq!(ext.resolve(&cfg.external).rate_hz, 0.0);
        let swept = ExternalParams { synapses_per_neuron: 33, rate_hz: 9.0 };
        assert_eq!(ext.resolve(&swept).synapses_per_neuron, 33, "must follow sweeps");
        assert_eq!(ext.resolve(&swept).rate_hz, 0.0, "explicit half must stick");
        // projection
        assert_eq!(cfg.projections.len(), 1);
        let p = &cfg.projections[0];
        assert_eq!((p.source.as_str(), p.target.as_str()), ("v1", "v2"));
        assert_eq!(p.conn.rule, ConnRule::Exponential);
        assert_eq!(p.conn.lambda_um, 200.0);
        assert_eq!(p.offset, (-1, 0));
        assert_eq!(p.stride, (Stride::downsample(2), Stride::ONE));
        assert!(!p.excitatory_only);
        assert_eq!(p.delay_base_ms, 3.0);
        assert_eq!(p.velocity_um_per_ms, 500.0);
        // atlas view
        let atlas = cfg.atlas();
        assert_eq!(atlas.len(), 2);
        assert_eq!(atlas.columns(), 36 + 16);
        assert_eq!(cfg.total_neurons(), (36 + 16) * 50);
        // legacy configs normalize to a one-area atlas
        let legacy = SimConfig::test_small();
        let one = legacy.area_list();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].grid.nx, legacy.grid.nx);
        assert_eq!(legacy.atlas().neurons(), legacy.grid.neurons());
    }

    #[test]
    fn area_blocks_resolve_registered_kernels() {
        let doc = toml::parse(
            "[[area]]\nname = \"a\"\nside = 4\nrule = \"flat-disc\"\nsigma_um = 50.0\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let k = cfg.areas[0].kernel.as_ref().expect("kernel resolved");
        assert_eq!(k.name(), "flat-disc");
        // 3σ disc radius derives from the overridden σ
        assert_eq!(k.prob_at(150.0), cfg.areas[0].conn.amplitude);
        assert_eq!(k.prob_at(151.0), 0.0);
    }

    #[test]
    fn area_numeric_overrides_rebind_an_inherited_registered_kernel() {
        // global rule is a registered (non-preset) kernel; an [[area]]
        // block overriding sigma_um without naming a rule must get a
        // kernel resolved against ITS numbers, not the stale global one
        let doc = toml::parse(
            "[connectivity]\nrule = \"flat-disc\"\nsigma_um = 100.0\n\n\
             [[area]]\nname = \"a\"\nside = 4\nsigma_um = 50.0\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let k = cfg.areas[0].kernel.as_ref().expect("kernel inherited");
        assert_eq!(k.name(), "flat-disc");
        // 3σ disc from the AREA's σ = 50 → radius 150, not 300
        assert_eq!(k.prob_at(150.0), cfg.areas[0].conn.amplitude);
        assert_eq!(k.prob_at(151.0), 0.0);
        // without numeric overrides the inherited kernel is kept as-is
        let doc = toml::parse(
            "[connectivity]\nrule = \"flat-disc\"\nsigma_um = 100.0\n\n\
             [[area]]\nname = \"a\"\nside = 4\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let k = cfg.areas[0].kernel.as_ref().unwrap();
        assert_eq!(k.prob_at(300.0), cfg.areas[0].conn.amplitude);
    }

    #[test]
    fn atlas_validation_rejects_bad_shapes() {
        let base = || {
            let mut c = SimConfig::test_small();
            c.areas = vec![
                AreaParams::new(
                    "a",
                    GridParams { neurons_per_column: 20, ..GridParams::square(4) },
                ),
                AreaParams::new(
                    "b",
                    GridParams { neurons_per_column: 20, ..GridParams::square(4) },
                ),
            ];
            c.projections = vec![ProjectionParams::new("a", "b")];
            c
        };
        assert!(base().validate().is_ok());
        let mut c = base();
        c.areas[1].name = "a".into();
        assert!(c.validate().unwrap_err().contains("duplicate"));
        let mut c = base();
        c.projections[0].target = "nope".into();
        assert!(c.validate().unwrap_err().contains("unknown area"));
        let mut c = base();
        c.projections[0].stride = (Stride { up: 1, down: 0 }, Stride::ONE);
        assert!(c.validate().unwrap_err().contains("stride"));
        let mut c = base();
        c.projections[0].stride = (Stride::ONE, Stride { up: 0, down: 2 });
        assert!(c.validate().unwrap_err().contains("stride"));
        let mut c = base();
        c.projections[0].velocity_um_per_ms = 0.0;
        assert!(c.validate().unwrap_err().contains("velocity"));
        // NaN must not slip through (NaN delays would saturate to 0 µs)
        let mut c = base();
        c.projections[0].delay_base_ms = f64::NAN;
        assert!(c.validate().unwrap_err().contains("delay_base_ms"));
        let mut c = base();
        c.projections[0].weight_scale = f64::NAN;
        assert!(c.validate().unwrap_err().contains("weight_scale"));
        let mut c = base();
        c.ranks = 17; // > 16 columns of area a
        assert!(c.validate().unwrap_err().contains("area"));
        let mut c = base();
        c.areas.clear();
        assert!(c.validate().unwrap_err().contains("projections require"));
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = SimConfig::test_small();
        c.ranks = 10_000;
        assert!(c.validate().unwrap_err().contains("ranks"));
        let mut c = SimConfig::test_small();
        c.syn.delay_min_ms = 0.0;
        assert!(c.validate().is_err());
        let mut c = SimConfig::test_small();
        c.conn.cutoff = 0.0;
        assert!(c.validate().is_err());
        // delay slots are u16: a delay/dt ratio past 65535 must be
        // rejected up front, not silently clamped (shortened) at build
        let mut c = SimConfig::test_small();
        c.dt_ms = 0.0005;
        c.syn.delay_min_ms = 0.0005;
        c.syn.delay_max_ms = 40.0;
        assert!(c.validate().unwrap_err().contains("delay-slot"));
        let mut c = SimConfig::test_small();
        c.grid.nx = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn negative_integers_error_instead_of_wrapping() {
        // regression: `as u32` casts wrapped negatives — `nx = -1` became
        // 4294967295 and passed every `== 0` validation
        let cases: [(&str, &str); 8] = [
            ("[network]\nnx = -1\n", "network.nx"),
            ("[network]\nneurons_per_column = -5\n", "network.neurons_per_column"),
            ("[external]\nsynapses_per_neuron = -1\n", "external.synapses_per_neuron"),
            ("[simulation]\nranks = -2\n", "simulation.ranks"),
            ("[simulation]\nseed = -3\n", "simulation.seed"),
            ("[[area]]\nname = \"a\"\nnx = -1\n", "'nx'"),
            ("[[area]]\nname = \"a\"\nneurons_per_column = -7\n", "'neurons_per_column'"),
            (
                "[[area]]\nname = \"a\"\n[[area]]\nname = \"b\"\n\
                 [[projection]]\nsource = \"a\"\ntarget = \"b\"\nstride_x = -2\n",
                "'stride_x'",
            ),
        ];
        for (toml_text, needle) in cases {
            let doc = toml::parse(toml_text).unwrap();
            let err = SimConfig::from_doc(&doc).unwrap_err();
            assert!(
                err.contains(needle) && err.contains('-'),
                "{toml_text:?} must name the offending key: {err}"
            );
        }
        // beyond-u32 values are rejected too, not truncated
        let doc = toml::parse("[network]\nside = 4294967296\n").unwrap();
        let err = SimConfig::from_doc(&doc).unwrap_err();
        assert!(err.contains("network.side"), "{err}");
        // area block errors carry the area name for multi-area configs
        let doc = toml::parse("[[area]]\nname = \"v1\"\nside = -4\n").unwrap();
        let err = SimConfig::from_doc(&doc).unwrap_err();
        assert!(err.contains("v1"), "{err}");
    }

    #[test]
    fn integer_keys_accept_the_exact_type_boundaries() {
        // u32 keys: u32::MAX is legal, one past it is rejected by name
        let doc = toml::parse("[t]\na = 4294967295\nb = 4294967296\n").unwrap();
        assert_eq!(u32_key(&doc, "t.a", "", 0).unwrap(), u32::MAX);
        let err = u32_key(&doc, "t.b", "", 0).unwrap_err();
        assert!(err.contains("'t.b'") && err.contains("4294967296"), "{err}");
        // i32 keys: both signed extremes are legal, one past each is not
        let doc = toml::parse("[t]\nlo = -2147483648\nhi = 2147483647\nover = 2147483648\n")
            .unwrap();
        assert_eq!(i32_key(&doc, "t.lo", "", 0).unwrap(), i32::MIN);
        assert_eq!(i32_key(&doc, "t.hi", "", 0).unwrap(), i32::MAX);
        let err = i32_key(&doc, "t.over", "", 0).unwrap_err();
        assert!(err.contains("'t.over'") && err.contains("32-bit"), "{err}");
        // the u64 seed accepts the full TOML (i64) integer range
        let doc = toml::parse("[simulation]\nseed = 9223372036854775807\n").unwrap();
        assert_eq!(SimConfig::from_doc(&doc).unwrap().seed, u64::try_from(i64::MAX).unwrap());
    }

    #[test]
    fn exc_fraction_extremes_do_not_underflow_inh() {
        let mut g = GridParams::square(2);
        g.exc_fraction = 1.0;
        assert_eq!(g.exc_per_column(), g.neurons_per_column);
        assert_eq!(g.inh_per_column(), 0);
        g.exc_fraction = 0.0;
        assert_eq!(g.exc_per_column(), 0);
        assert_eq!(g.inh_per_column(), g.neurons_per_column);
        // even an unvalidated out-of-range fraction must clamp, not
        // truncate through the f64 round-trip or underflow inh
        g.exc_fraction = 1.5;
        assert_eq!(g.exc_per_column(), g.neurons_per_column);
        assert_eq!(g.inh_per_column(), 0);
        g.exc_fraction = -0.5;
        assert_eq!(g.exc_per_column(), 0);
    }

    #[test]
    fn area_blocks_parse_per_area_neuron_models() {
        let doc = toml::parse(
            r#"
[neuron.exc]
g_c_over_cm = 0.03

[[area]]
name = "wake"
side = 4

[[area]]
name = "sws"
side = 4
exc_g_c_over_cm = 0.08
exc_tau_c_ms = 500.0
inh_tau_m_ms = 8.0
"#,
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        // wake inherits: no override stored
        assert!(cfg.areas[0].exc.is_none() && cfg.areas[0].inh.is_none());
        // sws: named keys override, unnamed keys inherit the resolved
        // global (which itself took the [neuron.exc] file override)
        let exc = cfg.areas[1].exc.expect("exc override");
        assert_eq!(exc.g_c_over_cm, 0.08);
        assert_eq!(exc.tau_c_ms, 500.0);
        assert_eq!(exc.tau_m_ms, cfg.exc.tau_m_ms);
        assert_eq!(cfg.exc.g_c_over_cm, 0.03);
        let inh = cfg.areas[1].inh.expect("inh override");
        assert_eq!(inh.tau_m_ms, 8.0);
        assert_eq!(inh.g_c_over_cm, 0.0);
    }

    #[test]
    fn per_area_neuron_models_are_validated() {
        let mk = |edit: fn(&mut NeuronParams)| {
            let mut c = SimConfig::test_small();
            let mut np = NeuronParams::excitatory();
            edit(&mut np);
            c.areas =
                vec![AreaParams::new("a", GridParams { neurons_per_column: 20, ..c.grid })
                    .exc_model(np)];
            c
        };
        assert!(mk(|_| {}).validate().is_ok());
        let err = mk(|np| np.tau_m_ms = 0.0).validate().unwrap_err();
        assert!(err.contains("tau_m_ms"), "{err}");
        let err = mk(|np| np.v_reset_mv = np.v_theta_mv).validate().unwrap_err();
        assert!(err.contains("v_theta_mv"), "{err}");
        // the XLA batch path accepts per-area τ/g̃/α_c overrides (the
        // SoA param table carries them), but a per-area override of the
        // shared scalars (E, θ, Vr, τarp) must stay a clean build error
        let mut c = mk(|np| np.g_c_over_cm = 0.08);
        c.solver = Solver::Xla;
        assert!(c.validate().is_ok(), "per-area SFA override must pass under xla");
        let mut c = mk(|np| np.v_theta_mv += 1.0);
        c.solver = Solver::Xla;
        let err = c.validate().unwrap_err();
        assert!(err.contains("shared E/θ/Vr/τarp"), "{err}");
        // differing *global* exc/inh shared scalars are caught too
        let mut c = SimConfig::test_small();
        c.solver = Solver::Xla;
        c.inh.tau_arp_ms = c.exc.tau_arp_ms + 1.0;
        let err = c.validate().unwrap_err();
        assert!(err.contains("neuron.inh"), "{err}");
    }

    #[test]
    fn backend_solver_consistency_is_validated() {
        let mut c = SimConfig::test_small();
        assert_eq!(c.backend, DynamicsBackend::Soa, "Soa must be the default backend");
        assert_eq!(c.dynamics_backend(), DynamicsBackend::Soa);
        c.backend = DynamicsBackend::Scalar;
        assert!(c.validate().is_ok());
        // xla solver forces the batch backend regardless of the knob
        c.solver = Solver::Xla;
        assert_eq!(c.dynamics_backend(), DynamicsBackend::Batch);
        // batch backend without the xla solver is a config error
        let mut c = SimConfig::test_small();
        c.backend = DynamicsBackend::Batch;
        let err = c.validate().unwrap_err();
        assert!(err.contains("solver = \"xla\""), "{err}");
        c.solver = Solver::Xla;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn atlas_is_capped_at_the_u8_param_space() {
        let mut c = SimConfig::test_small();
        let g = GridParams { neurons_per_column: 1, ..GridParams::square(1) };
        c.ranks = 1;
        c.areas = (0..129).map(|i| AreaParams::new(&format!("a{i}"), g)).collect();
        let err = c.validate().unwrap_err();
        assert!(err.contains("128 areas"), "{err}");
        c.areas.pop();
        assert!(c.validate().is_ok(), "128 areas must pass");
    }

    #[test]
    fn rational_strides_parse_and_map() {
        let doc = toml::parse(
            "[[area]]\nname = \"a\"\nside = 4\nneurons_per_column = 20\n\
             [[area]]\nname = \"b\"\nside = 8\nneurons_per_column = 20\n\
             [[projection]]\nsource = \"a\"\ntarget = \"b\"\nstride_up_x = 2\n\
             stride_up_y = 2\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let p = &cfg.projections[0];
        assert_eq!(p.stride, (Stride::upsample(2), Stride::upsample(2)));
        assert_eq!(p.stride.0.map(3), 6);
        // builder routes: downsample keeps PR-4 semantics, upsample and
        // fully-rational strides are new
        let p = ProjectionParams::new("a", "b").stride(2, 2);
        assert_eq!(p.stride.0.map(5), 2);
        let p = ProjectionParams::new("a", "b").upsample(3, 3);
        assert_eq!(p.stride.1.map(5), 15);
        let p = ProjectionParams::new("a", "b")
            .stride_rational(Stride { up: 3, down: 2 }, Stride::ONE);
        assert_eq!(p.stride.0.map(0), 0);
        assert_eq!(p.stride.0.map(1), 1); // 3/2 floors to 1
        assert_eq!(p.stride.0.map(2), 3);
    }

    #[test]
    fn delay_slots_cover_max_delay() {
        let c = SimConfig::test_small();
        assert!(c.delay_slots() as f64 * c.dt_ms > c.syn.delay_max_ms);
    }

    #[test]
    fn bad_rule_and_solver_strings() {
        assert!(ConnRule::parse("banana").is_err());
        assert!(Solver::parse("gpu").is_err());
        assert_eq!(ConnRule::parse("exp").unwrap(), ConnRule::Exponential);
        assert_eq!(Solver::parse("xla").unwrap(), Solver::Xla);
        assert!(DynamicsBackend::parse("simd").is_err());
        assert_eq!(DynamicsBackend::parse("scalar").unwrap(), DynamicsBackend::Scalar);
        assert_eq!(DynamicsBackend::parse("soa").unwrap(), DynamicsBackend::Soa);
        assert_eq!(DynamicsBackend::parse("batch").unwrap(), DynamicsBackend::Batch);
    }

    #[test]
    fn xla_rejects_time_driven_models_and_sampled_params() {
        // the batched artifact compiles exactly the LIF closed form:
        // registry models and per-neuron sampling must fail validation
        // by name, never silently fall back to the CPU paths
        let mut c = SimConfig::test_small();
        c.solver = Solver::Xla;
        c.exc.model = ModelKind::Izhikevich;
        let err = c.validate().unwrap_err();
        assert!(err.contains("supports only model = \"lif\""), "{err}");
        assert!(err.contains("izhikevich"), "{err}");

        let mut c = SimConfig::test_small();
        c.solver = Solver::Xla;
        c.inh.model = ModelKind::Adex;
        let err = c.validate().unwrap_err();
        assert!(err.contains("supports only model = \"lif\""), "{err}");
        assert!(err.contains("adex"), "{err}");

        let mut c = SimConfig::test_small();
        c.solver = Solver::Xla;
        c.exc.v_theta_dist = ParamDist { kind: DistKind::Gaussian, width: 1.0 };
        let err = c.validate().unwrap_err();
        assert!(err.contains("parameter distributions"), "{err}");
        // every one of these runs untouched on the event-driven solver
        let mut c = SimConfig::test_small();
        c.exc.model = ModelKind::Izhikevich;
        c.inh.model = ModelKind::Adex;
        c.exc.v_theta_dist = ParamDist { kind: DistKind::Gaussian, width: 1.0 };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn model_and_dist_keys_parse_from_toml() {
        let doc = toml::parse(
            "[neuron]\nmodel = \"izhikevich\"\n\
             [neuron.exc]\nizh_d = 10.0\nbias = 80.0\n\
             v_theta_dist = \"lorentzian\"\nv_theta_dist_width = 1.5\n\
             [neuron.inh]\nmodel = \"lif\"\n\
             tau_m_dist = \"gaussian\"\ntau_m_dist_width = 2.0\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        // the global [neuron] model applies to both populations; the
        // per-population key overrides it
        assert_eq!(cfg.exc.model, ModelKind::Izhikevich);
        assert_eq!(cfg.inh.model, ModelKind::Lif);
        assert_eq!(cfg.exc.izh.d, 10.0);
        assert_eq!(cfg.exc.bias, 80.0);
        assert_eq!(cfg.exc.v_theta_dist.kind, DistKind::Lorentzian);
        assert_eq!(cfg.exc.v_theta_dist.width, 1.5);
        assert_eq!(cfg.inh.tau_m_dist.kind, DistKind::Gaussian);
        assert_eq!(cfg.inh.tau_m_dist.width, 2.0);

        // per-area overrides and the projection weight_jitter knob
        let doc = toml::parse(
            "[[area]]\nname = \"a\"\nside = 4\nneurons_per_column = 20\n\
             exc_model = \"adex\"\nexc_adex_tau_w_ms = 100.0\n\
             exc_tau_m_dist = \"gaussian\"\nexc_tau_m_dist_width = 1.0\n\
             [[area]]\nname = \"b\"\nside = 4\nneurons_per_column = 20\n\
             [[projection]]\nsource = \"a\"\ntarget = \"b\"\nweight_jitter = 0.25\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.areas[0].exc.model, ModelKind::Adex);
        assert_eq!(cfg.areas[0].exc.adex.tau_w_ms, 100.0);
        assert_eq!(cfg.areas[0].exc.tau_m_dist.kind, DistKind::Gaussian);
        assert_eq!(cfg.areas[1].exc.model, ModelKind::Lif, "area b keeps the default");
        assert_eq!(cfg.projections[0].weight_jitter, 0.25);
    }

    #[test]
    fn weight_jitter_is_validated() {
        // from_doc validates, so the bad knob dies at load time
        let doc = toml::parse(
            "[[area]]\nname = \"a\"\nside = 4\nneurons_per_column = 20\n\
             [[area]]\nname = \"b\"\nside = 4\nneurons_per_column = 20\n\
             [[projection]]\nsource = \"a\"\ntarget = \"b\"\nweight_jitter = -0.5\n",
        )
        .unwrap();
        let err = SimConfig::from_doc(&doc).unwrap_err();
        assert!(err.contains("weight_jitter must be finite and >= 0"), "{err}");

        let doc = toml::parse(
            "[[area]]\nname = \"a\"\nside = 4\nneurons_per_column = 20\n\
             [[area]]\nname = \"b\"\nside = 4\nneurons_per_column = 20\n\
             [[projection]]\nsource = \"a\"\ntarget = \"b\"\nweight_jitter = 0.5\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.projections[0].weight_jitter, 0.5);
    }
}
