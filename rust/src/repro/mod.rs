//! Regeneration of every table and figure in the paper's evaluation
//! (the experiment index of DESIGN.md §5). The `cargo bench` targets and
//! the `dpsnn` CLI subcommands are thin wrappers over these functions,
//! each of which returns the printed report so tests can assert on it.

pub mod calibration_cache;
pub mod figures;

pub use calibration_cache::cached_calibration;
pub use figures::*;
