//! Table/figure regeneration functions (one per paper exhibit).
//!
//! Each returns the rendered report string. Scaling figures combine the
//! measured per-event calibration (real engine, this host) with the
//! virtual-cluster model (DESIGN.md §7). Event accounting uses the
//! *paper's* firing rates (7.5 Hz Gaussian, ~35 Hz exponential, §IV-B):
//! our affordable grids clip the 21×21 exponential stencil, so the
//! emergent rate-regime shift cannot fully express on them; the paper's
//! rates are the honest anchor for its own workloads (the measured rates
//! are printed alongside). Absolute ns/event reflects this host's core,
//! not 2015 Haswell — shapes and ratios are the reproduction target.

use crate::config::{ConnRule, SimConfig};
use crate::connectivity::analytic::{mean_offset_prob, table1_row};
use crate::connectivity::rules::Stencil;
use crate::geometry::Grid;
use crate::perfmodel::{weak_scaling_series, Calibration, ClusterParams, ScalingModel};
use crate::bench_harness::Table;

/// Paper §IV-B firing rates used for event accounting.
pub const PAPER_RATE_GAUSS_HZ: f64 = 7.5;
pub const PAPER_RATE_EXP_HZ: f64 = 35.0;

pub fn paper_rate(rule: ConnRule) -> f64 {
    match rule {
        ConnRule::Gaussian => PAPER_RATE_GAUSS_HZ,
        ConnRule::Exponential => PAPER_RATE_EXP_HZ,
    }
}

fn cfg_for(side: u32, rule: ConnRule) -> SimConfig {
    match rule {
        ConnRule::Gaussian => SimConfig::gaussian(side),
        ConnRule::Exponential => SimConfig::exponential(side),
    }
}

/// Build the scaling model for a rule from a (measured) calibration,
/// anchoring the rate to the paper's regime.
pub fn model_from(rule: ConnRule, measured: Calibration) -> ScalingModel {
    let anchored = Calibration { rate_hz: paper_rate(rule), ..measured };
    ScalingModel::new(ClusterParams::default(), anchored)
}

fn fmt_g(x: f64) -> String {
    format!("{:.2} G", x / 1e9)
}

// ---------------------------------------------------------------- Table I

/// Table I: problem sizes — analytic expectation vs the paper's numbers.
pub fn table1_report() -> String {
    let paper: [(u32, ConnRule, f64, f64, f64); 6] = [
        (24, ConnRule::Gaussian, 0.7e6, 0.9e9, 1.2e9),
        (48, ConnRule::Gaussian, 2.9e6, 3.5e9, 5.0e9),
        (96, ConnRule::Gaussian, 11.4e6, 14.2e9, 20.4e9),
        (24, ConnRule::Exponential, 0.7e6, 1.5e9, 1.8e9),
        (48, ConnRule::Exponential, 2.9e6, 5.9e9, 7.4e9),
        (96, ConnRule::Exponential, 11.4e6, 23.4e9, 29.6e9),
    ];
    let mut t = Table::new(&[
        "grid", "rule", "columns", "neurons", "recurrent(paper)", "recurrent(ours)",
        "total(paper)", "total(ours)", "err%",
    ]);
    for (side, rule, _n, rec_p, tot_p) in paper {
        let row = table1_row(side, rule);
        let err = (row.total - tot_p).abs() / tot_p * 100.0;
        t.row(&[
            format!("{side}x{side}"),
            rule.name().into(),
            format!("{}", side as u64 * side as u64),
            format!("{:.1} M", row.neurons as f64 / 1e6),
            fmt_g(rec_p),
            fmt_g(row.recurrent),
            fmt_g(tot_p),
            fmt_g(row.total),
            format!("{err:.1}"),
        ]);
    }
    let mut out = String::from("Table I - problem sizes (expected counts vs paper)\n");
    out.push_str(&t.render());
    let g = table1_row(24, ConnRule::Gaussian);
    let e = table1_row(24, ConnRule::Exponential);
    out.push_str(&format!(
        "\nper-neuron (bulk): gaussian {:.0} local + {:.0} remote ({:.0}% remote; paper ~990 + ~250, ~20%)\n",
        g.local_per_neuron, g.remote_per_neuron_bulk, g.remote_fraction_bulk * 100.0
    ));
    out.push_str(&format!(
        "                   exponential {:.0} local + {:.0} remote ({:.0}% remote; paper ~990 + ~1400, ~59%)\n",
        e.local_per_neuron, e.remote_per_neuron_bulk, e.remote_fraction_bulk * 100.0
    ));
    out
}

// ----------------------------------------------------------------- Fig. 2

/// Fig. 2: synapses (thousands) projected by the excitatory population
/// of one column into each column of its stencil.
pub fn fig2_report() -> String {
    let mut out = String::from(
        "Fig. 2 - lateral projection stencils (synapses in thousands from one column's\n\
         excitatory population; paper: 7x7 Gaussian ~250/neuron, 21x21 exponential ~1400/neuron)\n\n",
    );
    for rule in [ConnRule::Gaussian, ConnRule::Exponential] {
        let cfg = cfg_for(24, rule);
        let grid = Grid::new(cfg.grid);
        let stencil = Stencil::remote(&cfg.conn, &grid);
        let m = i32::try_from((stencil.bbox_side - 1) / 2).expect("stencil half-side fits i32");
        let exc = cfg.grid.exc_per_column() as f64;
        let npc = cfg.grid.neurons_per_column as f64;
        out.push_str(&format!(
            "{} (A={}, {}={} um): {}x{} stencil\n",
            rule.name(),
            cfg.conn.amplitude,
            if rule == ConnRule::Gaussian { "sigma" } else { "lambda" },
            if rule == ConnRule::Gaussian { cfg.conn.sigma_um } else { cfg.conn.lambda_um },
            stencil.bbox_side,
            stencil.bbox_side
        ));
        let mut total = 0.0;
        for dy in -m..=m {
            for dx in -m..=m {
                let k = if dx == 0 && dy == 0 {
                    // local: all 1240 neurons at p_local (for the map we
                    // show the column's own projections)
                    npc * (npc - 1.0) * cfg.conn.local_prob / 1000.0
                } else if stencil.offsets.iter().any(|o| (o.dx, o.dy) == (dx, dy)) {
                    let ep = mean_offset_prob(&cfg.conn, &grid, dx, dy);
                    exc * npc * ep / 1000.0
                } else {
                    0.0
                };
                if !(dx == 0 && dy == 0) {
                    total += k;
                }
                out.push_str(&(if k == 0.0 {
                    "    .".to_string()
                } else if k >= 100.0 {
                    format!("{k:5.0}")
                } else {
                    format!("{k:5.1}")
                }));
            }
            out.push('\n');
        }
        out.push_str(&format!(
            "remote synapses from the column: {:.0} k  (= {:.0}/neuron avg; paper ~{})\n\n",
            total,
            total * 1000.0 / npc,
            if rule == ConnRule::Gaussian { 250 } else { 1400 }
        ));
    }
    out
}

// ----------------------------------------------------------------- Fig. 5

fn ranks_for(side: u32) -> Vec<u32> {
    match side {
        24 => vec![1, 2, 4, 8, 16, 32, 64, 96],
        48 => vec![4, 8, 16, 32, 64, 96, 128, 256],
        96 => vec![64, 128, 256, 512, 1024],
        _ => vec![1, 2, 4, 8],
    }
}

/// Fig. 5: strong scaling, Gaussian connectivity (three grids).
pub fn fig5_report(cal: Calibration) -> String {
    let model = model_from(ConnRule::Gaussian, cal);
    let mut out = String::from(
        "Fig. 5 - strong scaling, Gaussian connectivity (modeled cluster; measured\n\
         per-event compute cost, see DESIGN.md par.7)\n\n",
    );
    let mut t = Table::new(&["grid", "procs", "ns/event", "compute", "comm", "speedup", "ideal"]);
    for side in [24u32, 48, 96] {
        let cfg = cfg_for(side, ConnRule::Gaussian);
        let ranks = ranks_for(side);
        let base = model.point(&cfg, ranks[0]);
        for &p in &ranks {
            let pt = model.point(&cfg, p);
            t.row(&[
                format!("{side}x{side}"),
                p.to_string(),
                format!("{:.2}", pt.ns_per_event),
                format!("{:.2}", pt.compute_ns),
                format!("{:.2}", pt.comm_ns),
                format!("{:.1}", base.ns_per_event / pt.ns_per_event),
                format!("{:.0}", p as f64 / ranks[0] as f64),
            ]);
        }
    }
    out.push_str(&t.render());
    // paper anchors
    let m24 = model.speedup(&cfg_for(24, ConnRule::Gaussian), 1, 96);
    let m48 = model.speedup(&cfg_for(48, ConnRule::Gaussian), 4, 256);
    let m96 = model.speedup(&cfg_for(96, ConnRule::Gaussian), 64, 1024);
    out.push_str(&format!(
        "\nspeedup anchors vs paper:\n\
         \x20 24x24 1->96 cores:   {m24:.1}x of ideal 96   (paper 67.3)\n\
         \x20 48x48 4->256 cores:  {m48:.1}x of ideal 64   (paper 54.2 'vs ideal 96')\n\
         \x20 96x96 64->1024:      {m96:.1}x of ideal 16   (paper 10.8)\n",
    ));
    out
}

// ----------------------------------------------------------------- Fig. 6

/// Fig. 6: weak scaling, Gaussian (six workloads per core).
pub fn fig6_report(cal: Calibration) -> String {
    let model = model_from(ConnRule::Gaussian, cal);
    let cfgs =
        [cfg_for(24, ConnRule::Gaussian), cfg_for(48, ConnRule::Gaussian), cfg_for(96, ConnRule::Gaussian)];
    let workloads = [13.8e6, 27.7e6, 36.9e6, 55.3e6, 73.8e6, 110.7e6];
    let mut out = String::from(
        "Fig. 6 - weak scaling, Gaussian (constant synapses/core; ideal = flat lines;\n\
         paper efficiency 72% at 110.7M/core down to 54% at 13.8M/core)\n\n",
    );
    let mut t = Table::new(&["syn/core", "procs", "ns/event", "wall s/sim-s", "efficiency%"]);
    for &w in &workloads {
        let series = weak_scaling_series(&model, &cfgs, w);
        if series.is_empty() {
            continue;
        }
        // weak scaling: total wall time per simulated second is
        // T(P) = ns/event x total events/s, and total events grow with P
        // at fixed synapses/core - ideal weak scaling keeps T flat, so
        // efficiency = T(P0)/T(P) = (ns0 x P0)/(ns x P).
        let (p0, ns0) = series[0];
        for &(p, ns) in &series {
            let wall = ns * (w * PAPER_RATE_GAUSS_HZ) * p as f64 / 1e9;
            let eff = (ns0 * p0 as f64) / (ns * p as f64) * 100.0;
            t.row(&[
                format!("{:.1} M", w / 1e6),
                p.to_string(),
                format!("{ns:.2}"),
                format!("{wall:.2}"),
                format!("{eff:.0}"),
            ]);
        }
    }
    out.push_str(&t.render());
    out
}

// ----------------------------------------------------------------- Fig. 7

/// Fig. 7: strong-scaling overlay, exponential vs Gaussian (24², 48²).
pub fn fig7_report(cal_g: Calibration, cal_e: Calibration) -> String {
    let mg = model_from(ConnRule::Gaussian, cal_g);
    let me = model_from(ConnRule::Exponential, cal_e);
    let mut out = String::from(
        "Fig. 7 - impact of lateral connectivity: time per synaptic event,\n\
         Gaussian (circles in the paper) vs exponential (diamonds)\n\n",
    );
    let mut t = Table::new(&["grid", "procs", "gauss ns/ev", "exp ns/ev", "ratio"]);
    for side in [24u32, 48] {
        let cg = cfg_for(side, ConnRule::Gaussian);
        let ce = cfg_for(side, ConnRule::Exponential);
        for &p in &ranks_for(side) {
            let g = mg.point(&cg, p);
            let e = me.point(&ce, p);
            t.row(&[
                format!("{side}x{side}"),
                p.to_string(),
                format!("{:.2}", g.ns_per_event),
                format!("{:.2}", e.ns_per_event),
                format!("{:.2}", e.ns_per_event / g.ns_per_event),
            ]);
        }
    }
    out.push_str(&t.render());
    let e24 = me.speedup(&cfg_for(24, ConnRule::Exponential), 1, 96) / 96.0;
    let e48 = me.speedup(&cfg_for(48, ConnRule::Exponential), 4, 96) / 24.0;
    out.push_str(&format!(
        "\nexponential scaling efficiency @96 cores: 24x24 {:.0}% (paper 79%), 48x48 {:.0}% (paper 83%)\n",
        e24 * 100.0,
        e48 * 100.0
    ));
    out
}

// ----------------------------------------------------------------- Fig. 8

/// Fig. 8: slowdown of the exponential rule per synaptic event
/// (paper: 1.9–2.3× over the Gaussian rule).
pub fn fig8_report(cal_g: Calibration, cal_e: Calibration) -> String {
    let mg = model_from(ConnRule::Gaussian, cal_g);
    let me = model_from(ConnRule::Exponential, cal_e);
    let mut out = String::from(
        "Fig. 8 - normalized cost ratio exponential/Gaussian per synaptic event\n\
         (paper: 1.9-2.3x; raw compute-cost ratio measured on this host shown too)\n\n",
    );
    let mut t = Table::new(&["grid", "procs", "ratio"]);
    let mut ratios = Vec::new();
    for side in [24u32, 48] {
        let cg = cfg_for(side, ConnRule::Gaussian);
        let ce = cfg_for(side, ConnRule::Exponential);
        for &p in &ranks_for(side) {
            let r = me.point(&ce, p).ns_per_event / mg.point(&cg, p).ns_per_event;
            ratios.push(r);
            t.row(&[format!("{side}x{side}"), p.to_string(), format!("{r:.2}")]);
        }
    }
    out.push_str(&t.render());
    let min = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = ratios.iter().cloned().fold(0.0, f64::max);
    out.push_str(&format!(
        "\nratio range: {min:.2} - {max:.2}  (paper: 1.9 - 2.3)\n\
         measured compute-only ratio: {:.2} (cal: exp {:.0} ns/ev / gauss {:.0} ns/ev)\n",
        cal_e.ns_per_event / cal_g.ns_per_event,
        cal_e.ns_per_event,
        cal_g.ns_per_event
    ));
    out
}

// ----------------------------------------------------------------- Fig. 9

/// Fig. 9: memory per synapse vs MPI processes.
pub fn fig9_report(cal_g: Calibration, cal_e: Calibration) -> String {
    let mut out = String::from(
        "Fig. 9 - memory occupation [bytes/synapse] (paper band: 26-34 B/synapse,\n\
         growing with processes due to MPI library buffers)\n\n",
    );
    let mut t = Table::new(&["grid", "rule", "procs", "B/synapse"]);
    for (rule, cal) in [(ConnRule::Gaussian, cal_g), (ConnRule::Exponential, cal_e)] {
        let model = model_from(rule, cal);
        for side in [24u32, 48, 96] {
            if rule == ConnRule::Exponential && side == 96 {
                continue; // paper measured exponential on 24² and 48² only
            }
            let cfg = cfg_for(side, rule);
            for &p in &ranks_for(side) {
                t.row(&[
                    format!("{side}x{side}"),
                    rule.name().into(),
                    p.to_string(),
                    format!("{:.1}", model.bytes_per_synapse(&cfg, p)),
                ]);
            }
        }
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "\nmeasured construction peak on this host: gaussian {:.1}, exponential {:.1} B/synapse\n\
         (resident store is 12 B/synapse as in the paper + 2 B precomputed delay slot;\n\
         peak adds the construction transient and delay-queue population, model adds\n\
         MPI allocation vs procs)\n",
        cal_g.peak_bytes_per_synapse, cal_e.peak_bytes_per_synapse
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cal(rule: ConnRule) -> Calibration {
        match rule {
            ConnRule::Gaussian => Calibration {
                ns_per_event: 130.0,
                rate_hz: 11.0,
                peak_bytes_per_synapse: 30.0,
            },
            ConnRule::Exponential => Calibration {
                ns_per_event: 200.0,
                rate_hz: 12.0,
                peak_bytes_per_synapse: 32.0,
            },
        }
    }

    #[test]
    fn table1_within_paper_rounding() {
        let r = table1_report();
        assert!(r.contains("24x24"));
        assert!(r.contains("96x96"));
        // every error column < 15% (skip title, header, separator lines)
        for line in r.lines().skip(3).take(6) {
            let err: f64 = line.split_whitespace().last().unwrap().parse().unwrap();
            assert!(err < 15.0, "row error {err}%: {line}");
        }
    }

    #[test]
    fn fig2_shows_both_stencils() {
        let r = fig2_report();
        assert!(r.contains("7x7 stencil"));
        assert!(r.contains("21x21 stencil"));
    }

    #[test]
    fn fig5_has_all_grid_series() {
        let r = fig5_report(cal(ConnRule::Gaussian));
        assert!(r.contains("24x24") && r.contains("48x48") && r.contains("96x96"));
        assert!(r.contains("1024"));
    }

    #[test]
    fn fig8_ratio_lands_in_paper_band() {
        let r = fig8_report(cal(ConnRule::Gaussian), cal(ConnRule::Exponential));
        // extract the ratio range line
        let line = r.lines().find(|l| l.starts_with("ratio range")).unwrap();
        let nums: Vec<f64> = line
            .split(|c: char| !c.is_ascii_digit() && c != '.')
            .filter(|s| !s.is_empty())
            .filter_map(|s| s.parse().ok())
            .collect();
        let (min, max) = (nums[0], nums[1]);
        assert!(min > 1.2 && max < 3.5, "ratio band {min}-{max} vs paper 1.9-2.3");
    }

    #[test]
    fn fig9_values_in_plausible_band() {
        let r = fig9_report(cal(ConnRule::Gaussian), cal(ConnRule::Exponential));
        for line in r.lines().filter(|l| l.contains("gaussian") || l.contains("exponential")) {
            if let Some(v) = line.split_whitespace().last().and_then(|s| s.parse::<f64>().ok())
            {
                assert!(v > 20.0 && v < 60.0, "B/synapse {v} out of band: {line}");
            }
        }
    }

    #[test]
    fn fig6_efficiencies_degrade_with_smaller_workload() {
        let r = fig6_report(cal(ConnRule::Gaussian));
        assert!(r.contains("13.8 M"));
        assert!(r.contains("110.7 M"));
    }
}
