//! Shared calibration cache: the scaling benches all need the measured
//! per-event cost of each connectivity rule; measuring takes tens of
//! seconds, so the first bench persists the numbers under `target/` and
//! later benches (or CLI invocations) reuse them.
//!
//! Calibration size: `DPSNN_QUICK=1` (or --quick) uses a 6×6 grid and
//! 60 ms — adequate for smoke runs; the default 8×8 grid / 100 ms keeps
//! per-synapse cache behaviour representative (full 1240-neuron columns,
//! ~1.2k synapses/neuron resident).

use std::path::PathBuf;

use crate::bench_harness::quick_mode;
use crate::config::ConnRule;
use crate::perfmodel::Calibration;

fn cache_path(rule: ConnRule, quick: bool) -> PathBuf {
    let tag = if quick { "quick" } else { "full" };
    PathBuf::from(format!("target/dpsnn_calibration_{}_{tag}.txt", rule.name()))
}

fn parse(text: &str) -> Option<Calibration> {
    let mut vals = text.split_whitespace().map(|t| t.parse::<f64>());
    Some(Calibration {
        ns_per_event: vals.next()?.ok()?,
        rate_hz: vals.next()?.ok()?,
        peak_bytes_per_synapse: vals.next()?.ok()?,
    })
}

/// Measured calibration for a rule, cached across processes.
pub fn cached_calibration(rule: ConnRule) -> Calibration {
    let quick = quick_mode();
    let path = cache_path(rule, quick);
    if let Ok(text) = std::fs::read_to_string(&path) {
        if let Some(cal) = parse(&text) {
            eprintln!(
                "[calibration] {} (cached): {:.0} ns/event, {:.1} Hz, {:.1} B/syn",
                rule.name(),
                cal.ns_per_event,
                cal.rate_hz,
                cal.peak_bytes_per_synapse
            );
            return cal;
        }
    }
    let (side, ms) = if quick { (6, 60.0) } else { (8, 100.0) };
    eprintln!("[calibration] measuring {} on {side}×{side}, {ms} ms ...", rule.name());
    let cal = Calibration::measure(rule, side, ms);
    eprintln!(
        "[calibration] {}: {:.0} ns/event, {:.1} Hz, {:.1} B/syn",
        rule.name(),
        cal.ns_per_event,
        cal.rate_hz,
        cal.peak_bytes_per_synapse
    );
    let _ = std::fs::create_dir_all("target");
    let _ = std::fs::write(
        &path,
        format!("{} {} {}", cal.ns_per_event, cal.rate_hz, cal.peak_bytes_per_synapse),
    );
    cal
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let c = parse("62.5 7.5 28.1").unwrap();
        assert_eq!(c.ns_per_event, 62.5);
        assert_eq!(c.rate_hz, 7.5);
        assert_eq!(c.peak_bytes_per_synapse, 28.1);
        assert!(parse("garbage").is_none());
        assert!(parse("1.0 2.0").is_none());
    }
}
