//! The shipped tree must be lint-clean.
//!
//! `dpsnn lint --deny` gates CI; this test is the same check wired
//! into `cargo test`, so a finding fails fast locally with the full
//! list instead of surfacing one job later. See docs/LINTS.md for the
//! rules and the allow-annotation syntax.

#![allow(clippy::cast_possible_truncation)]
#![allow(clippy::cast_sign_loss)]
#![allow(clippy::cast_possible_wrap)]

use std::path::Path;

#[test]
fn shipped_tree_has_zero_findings() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let findings = dpsnn::lint::lint_tree(&root).expect("lint walk over rust/src");
    let rendered: Vec<String> = findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule.name(), f.message))
        .collect();
    assert!(
        findings.is_empty(),
        "lint findings on the shipped tree (fix or annotate with a reason):\n{}",
        rendered.join("\n")
    );
}

#[test]
fn lint_walk_visits_nested_directories() {
    // a zero-findings result must mean "checked and clean", not
    // "skipped": plant a finding two directories deep and confirm the
    // walker reports it with the rule-scoping-relevant relative path
    let base = std::env::temp_dir().join(format!("dpsnn_lint_walk_{}", std::process::id()));
    let nested = base.join("config").join("deep");
    std::fs::create_dir_all(&nested).expect("create temp tree");
    std::fs::write(nested.join("x.rs"), "fn f(v: u64) -> u32 { v as u32 }\n")
        .expect("write probe file");
    let findings = dpsnn::lint::lint_tree(&base).expect("walk temp tree");
    std::fs::remove_dir_all(&base).ok();
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].file, "config/deep/x.rs");
}
