//! Integration: the distributed network (synapses, stimulus, spike
//! trains, metrics) is a pure function of the global seed — independent
//! of rank count, mapping strategy and delivery protocol.

// Cast clippy lints are package-wide warnings (Cargo.toml [lints]);
// the boundary modules are enforced by `dpsnn lint` (docs/LINTS.md).
#![allow(clippy::cast_possible_truncation)]
#![allow(clippy::cast_sign_loss)]
#![allow(clippy::cast_possible_wrap)]

// the deprecated one-shot wrapper is exercised deliberately: it must
// keep matching the staged pipeline
#![allow(deprecated)]

use dpsnn::config::SimConfig;
use dpsnn::coordinator::run_simulation;
use dpsnn::engine::RunOptions;
use dpsnn::geometry::Mapping;

fn cfg(ranks: u32) -> SimConfig {
    let mut c = SimConfig::test_small();
    c.duration_ms = 50.0;
    c.external.synapses_per_neuron = 100;
    c.external.rate_hz = 30.0;
    c.ranks = ranks;
    c
}

#[test]
fn activity_identical_across_rank_counts_and_mappings() {
    let mut reference: Option<Vec<Vec<u32>>> = None;
    for (ranks, mapping) in
        [(1, Mapping::Block), (2, Mapping::Block), (4, Mapping::Block), (4, Mapping::RoundRobin)]
    {
        let opts = RunOptions { mapping, record_activity: true, ..Default::default() };
        let s = run_simulation(&cfg(ranks), &opts);
        assert!(s.spikes() > 0);
        match &reference {
            None => reference = Some(s.activity),
            Some(r) => assert_eq!(
                r, &s.activity,
                "activity differs at ranks={ranks} mapping={mapping:?}"
            ),
        }
    }
}

#[test]
fn staged_pipeline_is_decomposition_invariant_too() {
    // the builder path must carry the same strongest property: one
    // network per rank count, identical probed activity
    use dpsnn::{ActivityProbe, SimulationBuilder};
    let mut reference: Option<Vec<Vec<u32>>> = None;
    for (ranks, mapping) in [(1, Mapping::Block), (3, Mapping::Block), (4, Mapping::RoundRobin)] {
        let mut net = SimulationBuilder::from_config(cfg(ranks))
            .mapping(mapping)
            .build()
            .expect("construction");
        let mut activity = ActivityProbe::new();
        {
            let mut session = net.session();
            session.attach(&mut activity);
            session.advance(50.0);
        }
        let rows = activity.into_rows();
        assert_eq!(rows.len(), 50);
        match &reference {
            None => reference = Some(rows),
            Some(r) => assert_eq!(
                r, &rows,
                "staged activity differs at ranks={ranks} mapping={mapping:?}"
            ),
        }
    }
}

#[test]
fn naive_delivery_matches_two_step_protocol() {
    let two_step = run_simulation(
        &cfg(3),
        &RunOptions { record_activity: true, ..Default::default() },
    );
    let naive = run_simulation(
        &cfg(3),
        &RunOptions { record_activity: true, naive_delivery: true, ..Default::default() },
    );
    assert_eq!(two_step.activity, naive.activity);
    // but the naive protocol moves messages between every pair each step
    let naive_msgs: u64 = naive.reports.iter().map(|r| r.spike_payload_msgs).sum();
    let two_msgs: u64 = two_step.reports.iter().map(|r| r.spike_payload_msgs).sum();
    assert!(
        naive_msgs >= two_msgs,
        "two-step should not send more payload messages: {two_msgs} vs {naive_msgs}"
    );
}

#[test]
fn different_seeds_give_different_networks() {
    let a = run_simulation(&cfg(2), &RunOptions::default());
    let mut c2 = cfg(2);
    c2.seed = 777;
    let b = run_simulation(&c2, &RunOptions::default());
    assert_ne!(a.spikes(), b.spikes());
    assert_ne!(a.synapses(), b.synapses());
}
