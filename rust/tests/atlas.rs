//! Integration: multi-area atlas composition.
//!
//! * A one-area atlas is **bit-identical** to the legacy single-grid
//!   path (same spike trains), across 1/2/4 ranks — the refactor's
//!   safety gate.
//! * A two-area network (feedforward + feedback projections, only area
//!   0 driven) is decomposition-invariant across rank counts and
//!   mappings, and replays bit-identically after `reset()`.
//! * The `configs/two_areas.toml` exemplar parses, builds and runs.

// Cast clippy lints are package-wide warnings (Cargo.toml [lints]);
// the boundary modules are enforced by `dpsnn lint` (docs/LINTS.md).
#![allow(clippy::cast_possible_truncation)]
#![allow(clippy::cast_sign_loss)]
#![allow(clippy::cast_possible_wrap)]

use dpsnn::config::{AreaParams, ConnParams, GridParams, SimConfig};
use dpsnn::geometry::Mapping;
use dpsnn::{ActivityProbe, ProjectionParams, SimulationBuilder};

fn legacy_cfg() -> SimConfig {
    let mut c = SimConfig::test_small(); // 4×4 grid, 50 n/col
    c.external.synapses_per_neuron = 100;
    c.external.rate_hz = 30.0;
    c
}

/// Per-step global column activity of a built network.
fn activity_of(builder: SimulationBuilder, ms: f64) -> Vec<Vec<u32>> {
    let mut net = builder.build().expect("construction");
    let mut probe = ActivityProbe::new();
    {
        let mut session = net.session();
        session.attach(&mut probe);
        session.advance(ms);
    }
    probe.into_rows()
}

#[test]
fn one_area_atlas_is_bit_identical_to_legacy_grid() {
    // the acceptance gate: wrapping the same grid in an explicit
    // one-area atlas must not change a single spike, on any rank count
    let cfg = legacy_cfg();
    for ranks in [1u32, 2, 4] {
        let legacy = activity_of(
            SimulationBuilder::from_config(cfg.clone()).ranks(ranks),
            40.0,
        );
        let atlas = activity_of(
            SimulationBuilder::from_config(cfg.clone()).area("solo", cfg.grid).ranks(ranks),
            40.0,
        );
        assert!(legacy.iter().flatten().any(|&n| n > 0), "reference run is silent");
        assert_eq!(legacy, atlas, "one-area atlas diverged from the grid path at {ranks} ranks");
    }
}

#[test]
fn one_area_toml_block_matches_the_plain_config() {
    // the [[area]] TOML route lands on the same network as the legacy
    // tables it inherits from
    let base = r#"
[network]
side = 4
neurons_per_column = 50

[external]
synapses_per_neuron = 100
rate_hz = 30.0

[simulation]
ranks = 2
"#;
    let legacy = activity_of(SimulationBuilder::from_toml_str(base).unwrap(), 30.0);
    let with_area = format!("{base}\n[[area]]\nname = \"solo\"\n");
    let atlas = activity_of(SimulationBuilder::from_toml_str(&with_area).unwrap(), 30.0);
    assert!(legacy.iter().flatten().any(|&n| n > 0));
    assert_eq!(legacy, atlas);
}

fn two_area_builder() -> SimulationBuilder {
    let g = GridParams { neurons_per_column: 40, ..GridParams::square(4) };
    let ff = ConnParams { amplitude: 0.3, ..ConnParams::gaussian() };
    SimulationBuilder::gaussian(4)
        .external(100, 100.0)
        .area("v1", g)
        .area_with(AreaParams::new("v2", g).external(0, 0.0))
        .project(ProjectionParams::new("v1", "v2").conn(ff).weight_scale(3.0))
        .project(ProjectionParams::new("v2", "v1"))
}

#[test]
fn two_area_activity_is_decomposition_invariant() {
    let mut reference: Option<Vec<Vec<u32>>> = None;
    for (ranks, mapping) in [
        (1u32, Mapping::Block),
        (2, Mapping::Block),
        (4, Mapping::Block),
        (4, Mapping::RoundRobin),
    ] {
        let rows = activity_of(two_area_builder().ranks(ranks).mapping(mapping), 50.0);
        // area 1 (columns 16..32) fires purely through the projections
        let v2: u32 = rows.iter().flat_map(|r| r[16..32].iter()).sum();
        assert!(v2 > 0, "undriven area silent at ranks={ranks} {mapping:?}");
        match &reference {
            None => reference = Some(rows),
            Some(r) => assert_eq!(
                r, &rows,
                "two-area activity differs at ranks={ranks} mapping={mapping:?}"
            ),
        }
    }
}

#[test]
fn two_area_network_reset_replays_identically() {
    let mut net = two_area_builder().ranks(2).build().expect("construction");
    let run = |net: &mut dpsnn::Network| {
        let mut probe = ActivityProbe::new();
        {
            let mut session = net.session();
            session.attach(&mut probe);
            session.advance(40.0);
        }
        probe.into_rows()
    };
    let first = run(&mut net);
    let synapses = net.synapses();
    net.reset();
    let replay = run(&mut net);
    assert!(first.iter().flatten().any(|&n| n > 0));
    assert_eq!(first, replay, "two-area reset must replay bit-identically");
    assert_eq!(net.synapses(), synapses, "reset must not touch the constructed atlas");
    // per-area summary totals survive the replay identically
    let totals = net.summary().area_totals;
    assert_eq!(totals.len(), 2);
    assert!(totals[1].spikes > 0);
}

#[test]
fn two_areas_toml_exemplar_builds_and_runs() {
    let text = std::fs::read_to_string("configs/two_areas.toml").expect("exemplar config");
    let builder = SimulationBuilder::from_toml_str(&text)
        .expect("exemplar parses")
        // shrink the demo size so the test stays quick; wiring, per-area
        // drive overrides and projections are what's under test
        .tune(|c| {
            for a in &mut c.areas {
                a.grid.neurons_per_column = 40;
            }
        });
    assert_eq!(builder.config().areas.len(), 2);
    assert_eq!(builder.config().projections.len(), 2);
    assert_eq!(builder.config().projections[0].weight_scale, 3.0);
    let mut net = builder.build().expect("exemplar builds");
    net.session().advance(30.0);
    let s = net.summary();
    assert_eq!(s.area_totals.len(), 2);
    assert!(s.area_totals[0].spikes > 0, "driven area silent");
    assert!(s.area_totals[1].spikes > 0, "projection-driven area silent");
}
