//! Integration: the cross-backend transport contract.
//!
//! The channel (threads + in-process channels) and shm (forked worker
//! processes + shared-memory rings) transports must be observationally
//! indistinguishable: identical spike trains across rank counts and
//! mappings, through reset-replay, and through a checkpoint/restore
//! cycle that crosses backends. The transport is selected explicitly
//! per network here, so a CI run that forces `DPSNN_TRANSPORT=shm`
//! does not vacuate the comparison — explicit config wins over the
//! environment default.

use dpsnn::config::SimConfig;
use dpsnn::engine::RunOptions;
use dpsnn::geometry::Mapping;
use dpsnn::{ActivityProbe, Network, SimulationBuilder, TransportKind};

fn cfg(ranks: u32) -> SimConfig {
    let mut c = SimConfig::test_small();
    c.external.synapses_per_neuron = 100;
    c.external.rate_hz = 30.0;
    c.ranks = ranks;
    c
}

fn build(ranks: u32, mapping: Mapping, transport: TransportKind) -> Network {
    SimulationBuilder::from_config(cfg(ranks))
        .mapping(mapping)
        .transport(transport)
        .build()
        .expect("construction")
}

/// Advance `ms` recording per-step global column activity.
fn run_recorded(net: &mut Network, ms: f64) -> Vec<Vec<u32>> {
    let mut activity = ActivityProbe::new();
    {
        let mut session = net.session();
        session.attach(&mut activity);
        session.advance(ms);
    }
    activity.into_rows()
}

#[test]
fn shm_backend_is_bit_identical_to_channel_across_ranks_and_mappings() {
    // the decomposition-invariance contract, extended across backends:
    // ONE reference spike train, reproduced by every (ranks, mapping,
    // transport) combination
    let reference = run_recorded(&mut build(1, Mapping::Block, TransportKind::Channel), 30.0);
    assert!(
        reference.iter().flatten().any(|&n| n > 0),
        "reference run must be active"
    );
    for (ranks, mapping) in [
        (1, Mapping::Block),
        (2, Mapping::Block),
        (4, Mapping::Block),
        (2, Mapping::RoundRobin),
        (4, Mapping::RoundRobin),
    ] {
        let mut net = build(ranks, mapping, TransportKind::Shm);
        let rows = run_recorded(&mut net, 30.0);
        assert_eq!(
            rows, reference,
            "shm diverged from channel at ranks={ranks} mapping={mapping:?}"
        );
    }
}

#[test]
fn shm_reset_replay_is_bit_identical() {
    let mut net = build(2, Mapping::Block, TransportKind::Shm);
    let synapses = net.synapses();
    let first = run_recorded(&mut net, 30.0);
    net.reset();
    let replay = run_recorded(&mut net, 30.0);
    assert_eq!(first, replay, "shm reset-replay diverged");
    assert_eq!(net.synapses(), synapses, "reset must not touch connectivity");
}

#[test]
fn checkpoint_restore_cycle_crosses_backends_bit_identically() {
    // run the channel network to t=20ms, checkpoint, restore the bytes
    // into a freshly-built shm network, and continue BOTH for another
    // 20ms: the two continuations must be bit-identical
    let mut channel = build(2, Mapping::Block, TransportKind::Channel);
    let _ = run_recorded(&mut channel, 20.0);
    let bytes = channel.checkpoint().expect("checkpoint");
    let tail_channel = run_recorded(&mut channel, 20.0);

    let mut shm = build(2, Mapping::Block, TransportKind::Shm);
    shm.restore(&bytes).expect("restore channel checkpoint into shm network");
    let tail_shm = run_recorded(&mut shm, 20.0);
    assert_eq!(tail_channel, tail_shm, "cross-backend restore diverged");

    // and the reverse direction: shm checkpoint into a channel network
    let bytes = shm.checkpoint().expect("shm checkpoint");
    let mut channel2 = build(2, Mapping::Block, TransportKind::Channel);
    channel2.restore(&bytes).expect("restore shm checkpoint into channel network");
    let tail2_shm = run_recorded(&mut shm, 10.0);
    let tail2_channel = run_recorded(&mut channel2, 10.0);
    assert_eq!(tail2_shm, tail2_channel, "reverse cross-backend restore diverged");
}

#[test]
fn hierarchical_construction_exchange_is_decomposition_invariant() {
    // the paper's two-step hierarchical Alltoallv reorders the
    // construction-phase payload exchange through per-node leaders; the
    // built network must be identical for every ranks_per_node grouping
    // (including one that does not divide the rank count)
    let reference = {
        let mut net = SimulationBuilder::from_config(cfg(4))
            .transport(TransportKind::Channel)
            .build()
            .expect("construction");
        (net.synapses(), run_recorded(&mut net, 30.0))
    };
    for rpn in [2u32, 3, 4] {
        let mut net = SimulationBuilder::from_config(cfg(4))
            .transport(TransportKind::Channel)
            .ranks_per_node(rpn)
            .build()
            .expect("construction");
        assert_eq!(net.synapses(), reference.0, "synapse totals differ at rpn={rpn}");
        let rows = run_recorded(&mut net, 30.0);
        assert_eq!(rows, reference.1, "dynamics diverged at ranks_per_node={rpn}");
    }
}

#[test]
fn shm_summary_and_metrics_match_channel() {
    // the Report round-trip through the shm command rings must carry
    // the same counters the thread backend reads directly
    let mut a = build(2, Mapping::Block, TransportKind::Channel);
    let mut b = build(2, Mapping::Block, TransportKind::Shm);
    let _ = run_recorded(&mut a, 25.0);
    let _ = run_recorded(&mut b, 25.0);
    let (sa, sb) = (a.summary(), b.summary());
    assert_eq!(sa.spikes(), sb.spikes());
    assert_eq!(sa.equivalent_events(), sb.equivalent_events());
    assert_eq!(sa.neurons, sb.neurons);
    assert_eq!(sa.synapses(), sb.synapses());
    let spikes_a: Vec<u64> = sa.reports.iter().map(|r| r.spikes).collect();
    let spikes_b: Vec<u64> = sb.reports.iter().map(|r| r.spikes).collect();
    assert_eq!(spikes_a, spikes_b, "per-rank spike counts differ across backends");
}

#[test]
fn explicit_shm_with_xla_solver_is_rejected() {
    let mut c = cfg(2);
    c.transport = Some(TransportKind::Shm);
    c.solver = dpsnn::config::Solver::Xla;
    let err = c.validate().expect_err("shm + xla must be rejected");
    assert!(err.contains("shm"), "{err}");
    assert!(err.contains("fork"), "{err}");
}

#[test]
fn set_external_sweeps_work_over_shm() {
    // stimulus sweeps route SetExternal commands through the cmd rings;
    // the swept shm run must match the swept channel run exactly
    let sweep = |transport: TransportKind| -> Vec<Vec<u32>> {
        let mut net = build(2, Mapping::Block, transport);
        let mut rows = run_recorded(&mut net, 15.0);
        net.set_external(100, 45.0);
        rows.extend(run_recorded(&mut net, 15.0));
        rows
    };
    assert_eq!(
        sweep(TransportKind::Channel),
        sweep(TransportKind::Shm),
        "swept runs diverged across backends"
    );
}

#[test]
fn run_options_still_apply_over_shm() {
    // naive delivery (full Alltoallv each step) must stay bit-identical
    // to the two-step subset protocol on the shm backend too
    let run = |naive: bool| -> Vec<Vec<u32>> {
        let opts = RunOptions { naive_delivery: naive, ..Default::default() };
        let mut net = SimulationBuilder::from_parts(cfg(3), opts)
            .transport(TransportKind::Shm)
            .build()
            .expect("construction");
        run_recorded(&mut net, 20.0)
    };
    assert_eq!(run(false), run(true), "naive vs two-step diverged over shm");
}
