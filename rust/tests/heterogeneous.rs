//! Integration: heterogeneous areas (PR 5).
//!
//! * A two-area composition with distinct per-area neuron models and
//!   drives, a downsampling feedforward and an **upsampling** feedback
//!   projection, swept mid-run with `Network::set_area_external`, is
//!   decomposition-invariant across 1/2/4 ranks × block/roundrobin.
//! * `reset()` replays bit-identically **through** a per-area sweep.
//! * A per-area model override equal to the globals is bit-identical to
//!   no override (the resolution path itself is exact).
//! * A fully-overridden area ignores global sweeps; a half-specified
//!   area follows them for its unspecified field (the PR-4 snapshot bug
//!   detached it permanently).

// Cast clippy lints are package-wide warnings (Cargo.toml [lints]);
// the boundary modules are enforced by `dpsnn lint` (docs/LINTS.md).
#![allow(clippy::cast_possible_truncation)]
#![allow(clippy::cast_sign_loss)]
#![allow(clippy::cast_possible_wrap)]

use dpsnn::config::{AreaParams, GridParams, NeuronParams};
use dpsnn::geometry::Mapping;
use dpsnn::{ActivityProbe, Network, ProjectionParams, SimulationBuilder};

/// A slow-wave-flavored two-area atlas: "wake" (4×4, default model,
/// global drive) and "sws" (2×2, strong SFA, its own hotter drive),
/// wired feedforward 2:1 down and feedback 1:2 up.
fn het_builder() -> SimulationBuilder {
    let big = GridParams { neurons_per_column: 40, ..GridParams::square(4) };
    let small = GridParams { neurons_per_column: 40, ..GridParams::square(2) };
    let mut slow = NeuronParams::excitatory();
    slow.g_c_over_cm = 0.08; // 4× the default adaptation strength
    slow.tau_c_ms = 400.0;
    SimulationBuilder::gaussian(4)
        .external(100, 60.0)
        .area("wake", big)
        .area_with(AreaParams::new("sws", small).exc_model(slow).external(100, 90.0))
        .project(ProjectionParams::new("wake", "sws").stride(2, 2).delay(2.0, 1000.0))
        .project(ProjectionParams::new("sws", "wake").upsample(2, 2).weight_scale(2.0))
}

/// Drive the heterogeneous net 20 ms, sweep the sws drive down, drive
/// 20 ms more; return the per-step global column activity.
fn sweep_run(ranks: u32, mapping: Mapping) -> Vec<Vec<u32>> {
    let mut net = het_builder().ranks(ranks).mapping(mapping).build().expect("construction");
    let mut probe = ActivityProbe::new();
    {
        let mut session = net.session();
        session.attach(&mut probe);
        session.advance(20.0);
    }
    net.set_area_external("sws", 100, 10.0).expect("sws sweep");
    {
        let mut session = net.session();
        session.attach(&mut probe);
        session.advance(20.0);
    }
    probe.into_rows()
}

#[test]
fn heterogeneous_sweep_run_is_decomposition_invariant() {
    let mut reference: Option<Vec<Vec<u32>>> = None;
    for (ranks, mapping) in [
        (1u32, Mapping::Block),
        (2, Mapping::Block),
        (4, Mapping::Block),
        (4, Mapping::RoundRobin),
    ] {
        let rows = sweep_run(ranks, mapping);
        assert_eq!(rows.len(), 40);
        // wake columns 0..16, sws columns 16..20
        let wake: u64 = rows.iter().flat_map(|r| r[..16].iter()).map(|&n| n as u64).sum();
        let sws_before: u64 =
            rows[..20].iter().flat_map(|r| r[16..20].iter()).map(|&n| n as u64).sum();
        assert!(wake > 0, "wake silent at ranks={ranks} {mapping:?}");
        assert!(sws_before > 0, "sws silent before the sweep at ranks={ranks}");
        match &reference {
            None => reference = Some(rows),
            Some(r) => assert_eq!(
                r, &rows,
                "heterogeneous sweep run differs at ranks={ranks} mapping={mapping:?}"
            ),
        }
    }
    // the sweep must actually bite: sws activity drops once its drive
    // falls from 90 Hz to 10 Hz (recurrence and feedforward remain)
    let rows = reference.unwrap();
    let sws_before: u64 =
        rows[..20].iter().flat_map(|r| r[16..20].iter()).map(|&n| n as u64).sum();
    let sws_after: u64 =
        rows[20..].iter().flat_map(|r| r[16..20].iter()).map(|&n| n as u64).sum();
    assert!(
        sws_after < sws_before,
        "cutting the sws drive must reduce its activity ({sws_before} -> {sws_after})"
    );
}

#[test]
fn reset_replays_identically_through_a_per_area_sweep() {
    let mut net = het_builder().ranks(2).build().expect("construction");
    let run = |net: &mut Network| -> Vec<Vec<u32>> {
        let mut probe = ActivityProbe::new();
        {
            let mut session = net.session();
            session.attach(&mut probe);
            session.advance(15.0);
        }
        net.set_area_external("sws", 100, 10.0).expect("sweep");
        {
            let mut session = net.session();
            session.attach(&mut probe);
            session.advance(15.0);
        }
        probe.into_rows()
    };
    let first = run(&mut net);
    // restore the constructed sws drive (100 syn, 90 Hz), then rewind:
    // the replay must retrace the run — including the mid-run sweep —
    // bit for bit
    net.set_area_external("sws", 100, 90.0).expect("restore");
    net.reset();
    let replay = run(&mut net);
    assert!(first.iter().flatten().any(|&n| n > 0));
    assert_eq!(first, replay, "reset must replay bit-identically through the sweep");
}

#[test]
fn model_override_equal_to_globals_is_bit_identical() {
    // resolving a per-area model must be exact: overriding with the
    // global parameters changes nothing, on any rank count
    let g = GridParams { neurons_per_column: 40, ..GridParams::square(3) };
    let run = |explicit: bool, ranks: u32| -> Vec<Vec<u32>> {
        let b = SimulationBuilder::gaussian(3).external(100, 60.0).area("a", g);
        let second = if explicit {
            AreaParams::new("b", g)
                .exc_model(NeuronParams::excitatory())
                .inh_model(NeuronParams::inhibitory())
        } else {
            AreaParams::new("b", g)
        };
        let mut net = b.area_with(second).ranks(ranks).build().expect("construction");
        let mut probe = ActivityProbe::new();
        {
            let mut session = net.session();
            session.attach(&mut probe);
            session.advance(25.0);
        }
        probe.into_rows()
    };
    for ranks in [1u32, 2] {
        let implicit = run(false, ranks);
        let explicit = run(true, ranks);
        assert!(implicit.iter().flatten().any(|&n| n > 0));
        assert_eq!(implicit, explicit, "explicit global model diverged at {ranks} ranks");
    }
}

#[test]
fn slow_wave_toml_exemplar_builds_and_runs() {
    let text =
        std::fs::read_to_string("configs/slow_wave_two_areas.toml").expect("exemplar config");
    let builder = SimulationBuilder::from_toml_str(&text)
        .expect("exemplar parses")
        // shrink the demo so the test stays quick; the per-area model
        // keys, partial drive override and rational strides are what's
        // under test
        .tune(|c| {
            for a in &mut c.areas {
                a.grid.neurons_per_column = 40;
            }
        });
    let cfg = builder.config();
    assert_eq!(cfg.areas.len(), 2);
    let sws = &cfg.areas[1];
    assert_eq!(sws.exc.expect("exc override").g_c_over_cm, 0.08);
    assert_eq!(sws.exc.expect("exc override").tau_c_ms, 500.0);
    assert!(sws.inh.is_none(), "no inh_* keys -> inherit the global model");
    assert_eq!(sws.external.rate_hz, Some(70.0));
    assert_eq!(sws.external.synapses_per_neuron, None, "rate-only override");
    assert_eq!(cfg.projections[0].stride.0, dpsnn::Stride::downsample(2));
    assert_eq!(cfg.projections[1].stride.0, dpsnn::Stride::upsample(2));
    let mut net = builder.build().expect("exemplar builds");
    net.session().advance(30.0);
    let s = net.summary();
    assert_eq!(s.area_totals.len(), 2);
    assert!(s.area_totals[0].spikes > 0, "wake silent");
    assert!(s.area_totals[1].spikes > 0, "sws silent");
}

#[test]
fn full_override_ignores_global_sweeps_and_half_override_follows() {
    // "h" overrides only the rate (follows global synapse count);
    // "f" overrides both fields (detached from global sweeps)
    let g = GridParams { neurons_per_column: 40, ..GridParams::square(3) };
    let run = |sweep: bool| -> Vec<Vec<u32>> {
        let mut net = SimulationBuilder::gaussian(3)
            .external(100, 40.0)
            .area_with(AreaParams::new("h", g).external_rate(40.0))
            .area_with(AreaParams::new("f", g).external(100, 40.0))
            .ranks(2)
            .build()
            .expect("construction");
        let mut probe = ActivityProbe::new();
        {
            let mut session = net.session();
            session.attach(&mut probe);
            session.advance(20.0);
        }
        if sweep {
            // zero the global synapse bundle, same rate
            net.set_external(0, 40.0);
        }
        {
            let mut session = net.session();
            session.attach(&mut probe);
            session.advance(20.0);
        }
        probe.into_rows()
    };
    let plain = run(false);
    let swept = run(true);
    assert_eq!(plain[..20], swept[..20], "identical until the sweep");
    // h (columns 0..9): its synapse count follows the global sweep to
    // zero — external drive gone, activity collapses
    let h_spikes = |rows: &[Vec<u32>]| -> u64 {
        rows[20..].iter().flat_map(|r| r[..9].iter()).map(|&n| n as u64).sum()
    };
    assert!(h_spikes(&plain) > 0);
    assert!(
        h_spikes(&swept) < h_spikes(&plain) / 2,
        "half-specified area must follow the global sweep: {} vs {}",
        h_spikes(&swept),
        h_spikes(&plain)
    );
    // f (columns 9..18): fully overridden — the global sweep must not
    // even reseed its calendar; its activity is bit-identical
    let f_cols = |rows: &[Vec<u32>]| -> Vec<Vec<u32>> {
        rows[20..].iter().map(|r| r[9..18].to_vec()).collect()
    };
    assert_eq!(
        f_cols(&plain),
        f_cols(&swept),
        "fully-overridden area must be untouched by the global sweep"
    );
}
