//! Integration: checkpoint/restore of a running network.
//!
//! * A restored checkpoint resumes **bit-identically**: the activity
//!   rows after restore equal the rows of the never-interrupted run,
//!   across 1/2/4 ranks × block/round-robin mappings, into a fresh
//!   network and as a rewind of the original.
//! * Restore validates the identity of the target network field by
//!   field (seed, ranks, mapping) with named errors.
//! * Corrupted, truncated and future-version bytes are rejected with
//!   named errors — never a panic.
//! * A rebased restore re-zeroes the time origin and lets the run
//!   cross the ~71.6 min u32-µs spike-timestamp horizon.

use dpsnn::checkpoint::ENVELOPE_VERSION_OFFSET;
use dpsnn::config::SimConfig;
use dpsnn::engine::plasticity::StdpParams;
use dpsnn::engine::RunOptions;
use dpsnn::geometry::Mapping;
use dpsnn::{ActivityProbe, Network, SimulationBuilder};

fn cfg(ranks: u32) -> SimConfig {
    let mut c = SimConfig::test_small();
    c.external.synapses_per_neuron = 100;
    c.external.rate_hz = 30.0;
    c.ranks = ranks;
    c
}

fn build(ranks: u32, mapping: Mapping) -> Network {
    let opts = RunOptions { mapping, ..Default::default() };
    SimulationBuilder::from_parts(cfg(ranks), opts).build().expect("construction")
}

/// Advance `ms` recording per-step global column activity.
fn run_recorded(net: &mut Network, ms: f64) -> Vec<Vec<u32>> {
    let mut activity = ActivityProbe::new();
    {
        let mut session = net.session();
        session.attach(&mut activity);
        session.advance(ms);
    }
    activity.into_rows()
}

#[test]
fn restore_resumes_bit_identically_across_ranks_and_mappings() {
    for mapping in [Mapping::Block, Mapping::RoundRobin] {
        for ranks in [1u32, 2, 4] {
            let mut net = build(ranks, mapping);
            net.session().advance(20.0);
            let bytes = net.checkpoint().expect("checkpoint");
            let uninterrupted = run_recorded(&mut net, 25.0);
            assert!(
                uninterrupted.iter().flatten().any(|&n| n > 0),
                "reference must be active ({ranks} ranks, {mapping:?})"
            );

            // a fresh identically-configured network resumes the bytes
            let mut resumed = build(ranks, mapping);
            resumed.restore(&bytes).expect("restore into a fresh network");
            assert_eq!(
                run_recorded(&mut resumed, 25.0),
                uninterrupted,
                "restored run diverged ({ranks} ranks, {mapping:?})"
            );

            // and the original network rewinds onto its own checkpoint
            net.restore(&bytes).expect("rewind");
            assert_eq!(
                run_recorded(&mut net, 25.0),
                uninterrupted,
                "rewound run diverged ({ranks} ranks, {mapping:?})"
            );
        }
    }
}

#[test]
fn restore_resumes_bit_identically_with_stdp() {
    let mk = || {
        SimulationBuilder::from_config(cfg(2))
            .plasticity(StdpParams::default())
            .build()
            .expect("construction")
    };
    let mut net = mk();
    net.session().advance(20.0);
    let bytes = net.checkpoint().expect("checkpoint");
    let uninterrupted = run_recorded(&mut net, 20.0);

    let mut resumed = mk();
    resumed.restore(&bytes).expect("restore");
    assert_eq!(
        run_recorded(&mut resumed, 20.0),
        uninterrupted,
        "STDP run diverged after restore (weights or traces not carried)"
    );
}

#[test]
fn restore_rejects_mismatched_networks_by_name() {
    let mut net = build(2, Mapping::Block);
    net.session().advance(10.0);
    let bytes = net.checkpoint().expect("checkpoint");

    // different seed
    let mut c = cfg(2);
    c.seed += 1;
    let mut other = SimulationBuilder::from_config(c).build().expect("construction");
    let err = other.restore(&bytes).unwrap_err();
    assert!(err.contains("seed"), "{err}");

    // different rank count
    let err = build(4, Mapping::Block).restore(&bytes).unwrap_err();
    assert!(err.contains("ranks"), "{err}");

    // different mapping
    let err = build(2, Mapping::RoundRobin).restore(&bytes).unwrap_err();
    assert!(err.contains("mapping"), "{err}");

    // plasticity on vs off
    let err = SimulationBuilder::from_config(cfg(2))
        .plasticity(StdpParams::default())
        .build()
        .expect("construction")
        .restore(&bytes)
        .unwrap_err();
    assert!(err.contains("plasticity"), "{err}");

    // the checkpointed network itself is untouched by the rejections
    net.restore(&bytes).expect("original still restores");
}

#[test]
fn damaged_bytes_are_rejected_with_named_errors() {
    let mut net = build(1, Mapping::Block);
    net.session().advance(10.0);
    let bytes = net.checkpoint().expect("checkpoint");

    // flip one payload byte: hash trailer catches it
    let mut corrupt = bytes.clone();
    corrupt[bytes.len() / 2] ^= 0x40;
    let err = net.restore(&corrupt).unwrap_err();
    assert!(err.contains("corrupted"), "{err}");

    // truncation at every kind of boundary
    for cut in [0, 4, 19, bytes.len() / 2, bytes.len() - 1] {
        assert!(net.restore(&bytes[..cut]).is_err(), "truncated at {cut} accepted");
    }

    // future format version is named, not reported as corruption
    let mut future = bytes.clone();
    future[ENVELOPE_VERSION_OFFSET] = 0xFE;
    let err = net.restore(&future).unwrap_err();
    assert!(err.contains("version"), "{err}");

    // foreign bytes
    let err = net.restore(b"not a checkpoint").unwrap_err();
    assert!(err.contains("magic") || err.contains("truncated"), "{err}");

    // after all the rejections the intact bytes still restore
    net.restore(&bytes).expect("intact bytes restore");
}

#[test]
fn rebased_restore_crosses_the_wire_time_horizon() {
    // one-minute steps with a silent drive: only the clock matters.
    // 60 steps put the run at 3.6e6 ms of simulated time, ~84% of the
    // ~4.295e6 ms u32-µs horizon.
    let mut c = cfg(2);
    c.dt_ms = 60_000.0;
    c.external.rate_hz = 0.0;
    let mut net = SimulationBuilder::from_config(c).build().expect("construction");
    net.session().advance(3_600_000.0);
    assert_eq!(net.steps_run(), 60);

    // without a rebase the session refuses to cross the horizon
    let err = net.session().try_advance(3_000_000.0).unwrap_err();
    assert!(err.contains("horizon"), "{err}");

    let bytes = net.checkpoint().expect("checkpoint");
    net.restore_rebased(&bytes).expect("rebased restore");
    // the origin moved to one step before the checkpoint: 59 steps of
    // budget were reclaimed
    assert_eq!(net.steps_run(), 1);
    net.session()
        .try_advance(3_000_000.0)
        .expect("rebase must refill the horizon budget");
    assert_eq!(net.steps_run(), 51, "50 more one-minute steps after the rebase");
}
