//! Integration: the XLA batched solver (L1 Pallas kernel → HLO artifact
//! → PJRT) drives the full distributed engine end-to-end, and its
//! network statistics agree with the exact event-driven solver.
//!
//! The batched path aggregates each step's events into one jump, so the
//! two solvers produce *statistically* equivalent — not identical —
//! spike trains; we compare population firing rates.

// Cast clippy lints are package-wide warnings (Cargo.toml [lints]);
// the boundary modules are enforced by `dpsnn lint` (docs/LINTS.md).
#![allow(clippy::cast_possible_truncation)]
#![allow(clippy::cast_sign_loss)]
#![allow(clippy::cast_possible_wrap)]

use dpsnn::config::{NeuronParams, SimConfig, Solver};
use dpsnn::coordinator::{RunSummary, SimulationBuilder};
use dpsnn::{AreaParams, ProjectionParams};

fn cfg(solver: Solver) -> SimConfig {
    let mut c = SimConfig::test_small();
    c.grid.neurons_per_column = 128; // 4×4 grid → 2048 neurons → batch 4096
    c.duration_ms = 60.0;
    c.external.synapses_per_neuron = 100;
    c.external.rate_hz = 30.0;
    c.ranks = 2;
    c.solver = solver;
    c
}

fn artifacts_available() -> bool {
    // the batched solver needs both the compiled-in PJRT client
    // (`--features xla`) and the AOT artifacts (`make artifacts`)
    cfg!(feature = "xla")
        && dpsnn::runtime::pjrt::artifacts_dir().join("lif_step_1024.hlo.txt").exists()
}

fn run(solver: Solver) -> RunSummary {
    // staged pipeline: both solvers drive the same constructed network
    // machinery (construct once, one 60 ms session)
    let mut net = SimulationBuilder::from_config(cfg(solver)).build().expect("construction");
    net.session().advance(60.0);
    net.summary()
}

#[test]
fn xla_solver_runs_the_full_engine() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let s = run(Solver::Xla);
    assert!(s.spikes() > 0, "XLA-solved network must be active");
    assert!(s.recurrent_events() > 0, "spikes must propagate through synapses");
}

#[test]
fn xla_and_event_driven_rates_agree() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let ev = run(Solver::EventDriven);
    let xla = run(Solver::Xla);
    let (r_ev, r_xla) = (ev.firing_rate_hz(), xla.firing_rate_hz());
    assert!(r_ev > 0.0 && r_xla > 0.0);
    let ratio = r_xla / r_ev;
    assert!(
        (0.5..2.0).contains(&ratio),
        "rates diverge: event {r_ev:.2} Hz vs xla {r_xla:.2} Hz"
    );
    // external drive is identical by construction (same seeded streams)
    assert_eq!(ev.reports.iter().map(|r| r.external_events).sum::<u64>(),
               xla.reports.iter().map(|r| r.external_events).sum::<u64>());
}

/// Schema-5 SoA rewiring lifted the "no per-area neuron models under
/// XLA" validation: `BatchSolver::from_soa` builds its per-neuron f32
/// constant lanes straight from the SoA parameter table, so per-area
/// τ_m/τ_c/g̃/α_c overrides now compile into the batched path (shared
/// E/θ/Vr/τ_arp still required). Both solvers must accept the same
/// heterogeneous atlas and agree on rates.
#[test]
fn per_area_models_run_under_the_batch_solver() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let run_het = |solver: Solver| -> RunSummary {
        let mut slow_exc = NeuronParams::excitatory();
        slow_exc.g_c_over_cm = 0.08; // 4× adaptation, batch-compatible
        slow_exc.tau_c_ms = 500.0;
        let base = cfg(solver);
        // halve the per-area neuron count so the two-area total (2048)
        // matches the single-area runs and their compiled batch shape
        let mut g = base.grid;
        g.neurons_per_column = 64;
        let mut net = SimulationBuilder::from_config(base)
            .area("wake", g)
            .area_with(AreaParams::new("sws", g).exc_model(slow_exc))
            .project(ProjectionParams::new("wake", "sws"))
            .build()
            .expect("heterogeneous atlas must be accepted by both solvers");
        net.session().advance(60.0);
        net.summary()
    };
    let ev = run_het(Solver::EventDriven);
    let xla = run_het(Solver::Xla);
    let (r_ev, r_xla) = (ev.firing_rate_hz(), xla.firing_rate_hz());
    assert!(r_ev > 0.0 && r_xla > 0.0, "both heterogeneous runs must be active");
    let ratio = r_xla / r_ev;
    assert!(
        (0.5..2.0).contains(&ratio),
        "heterogeneous rates diverge: event {r_ev:.2} Hz vs xla {r_xla:.2} Hz"
    );
}

#[test]
fn xla_solver_is_deterministic() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let a = run(Solver::Xla);
    let b = run(Solver::Xla);
    assert_eq!(a.spikes(), b.spikes());
    assert_eq!(a.recurrent_events(), b.recurrent_events());
}
