//! Integration: the persistent rank executor's lifecycle.
//!
//! * Spike trains through the pool are bit-identical to driving
//!   `RankProcess::step` directly (no pool), across 1/2/4 ranks.
//! * `reset()` replays bit-identically through a *reused* pool.
//! * A panic inside a rank surfaces its payload, poisons the session
//!   (no further stepping, clear error) and never deadlocks the step
//!   collectives.
//! * Dropping a `Network` without any explicit shutdown terminates the
//!   worker threads cleanly.

// Cast clippy lints are package-wide warnings (Cargo.toml [lints]);
// the boundary modules are enforced by `dpsnn lint` (docs/LINTS.md).
#![allow(clippy::cast_possible_truncation)]
#![allow(clippy::cast_sign_loss)]
#![allow(clippy::cast_possible_wrap)]

use std::panic::{catch_unwind, AssertUnwindSafe};

use dpsnn::config::SimConfig;
use dpsnn::engine::{FaultPlan, RankProcess, RunOptions};
use dpsnn::geometry::{Decomposition, Grid, Mapping};
use dpsnn::mpi::run_cluster;
use dpsnn::{ActivityProbe, SimulationBuilder, SpikeCountProbe};

fn cfg(ranks: u32) -> SimConfig {
    let mut c = SimConfig::test_small();
    c.external.synapses_per_neuron = 100;
    c.external.rate_hz = 30.0;
    c.ranks = ranks;
    c
}

/// Reference: per-step global column spike counts from driving the rank
/// processes directly on one-shot cluster threads — the engine without
/// any executor in front of it.
fn reference_activity(ranks: u32, steps: u64) -> Vec<Vec<u32>> {
    let c = cfg(ranks);
    let ncols = c.grid.columns() as usize;
    let results = run_cluster(ranks, move |mut comm| {
        let grid = Grid::new(c.grid);
        let decomp = Decomposition::new(&grid, comm.ranks(), Mapping::Block);
        let opts = RunOptions::default();
        let mut proc = RankProcess::construct(&c, &decomp, &mut comm, &opts);
        proc.set_observe(true);
        let cols = proc.my_columns().to_vec();
        let mut rows: Vec<Vec<u32>> = Vec::with_capacity(steps as usize);
        for s in 0..steps {
            proc.step(&mut comm, s);
            rows.push(proc.step_col_spikes().to_vec());
        }
        (cols, rows)
    });
    let mut global = vec![vec![0u32; ncols]; steps as usize];
    for (cols, rows) in results {
        for (row, grow) in rows.iter().zip(global.iter_mut()) {
            for (i, &col) in cols.iter().enumerate() {
                grow[col as usize] = row[i];
            }
        }
    }
    global
}

/// The same activity through the persistent pool (`Network` + probe).
fn pool_activity(ranks: u32, steps: u64) -> Vec<Vec<u32>> {
    let mut net = SimulationBuilder::from_config(cfg(ranks)).build().expect("construction");
    let mut activity = ActivityProbe::new();
    {
        let mut session = net.session();
        session.attach(&mut activity);
        session.advance(steps as f64);
    }
    activity.into_rows()
}

#[test]
fn pool_matches_direct_stepping_across_rank_counts() {
    let steps = 30u64;
    let reference = reference_activity(1, steps);
    assert!(reference.iter().flatten().any(|&n| n > 0), "reference must be active");
    for ranks in [1u32, 2, 4] {
        assert_eq!(
            reference_activity(ranks, steps),
            reference,
            "direct stepping not decomposition-invariant at {ranks} ranks"
        );
        assert_eq!(
            pool_activity(ranks, steps),
            reference,
            "pool diverges from direct stepping at {ranks} ranks"
        );
    }
}

#[test]
fn reset_replays_bit_identically_through_a_reused_pool() {
    let mut net = SimulationBuilder::from_config(cfg(2)).build().expect("construction");
    let run = |net: &mut dpsnn::Network| {
        let mut activity = ActivityProbe::new();
        {
            let mut session = net.session();
            session.attach(&mut activity);
            session.advance(25.0);
        }
        activity.into_rows()
    };
    let first = run(&mut net);
    assert!(first.iter().flatten().any(|&n| n > 0));
    // Reset is a command through the SAME worker pool — no thread
    // teardown; the replay must be bit-identical
    net.reset();
    let replay = run(&mut net);
    assert_eq!(first, replay, "reset replay diverged through the reused pool");
    assert_eq!(net.steps_run(), 25);
}

#[test]
fn probed_and_unprobed_advance_agree_on_the_pool() {
    let mut plain = SimulationBuilder::from_config(cfg(2)).build().expect("construction");
    plain.session().advance(30.0);
    let expected = plain.summary().spikes();
    assert!(expected > 0);

    // probed: one command per step instead of one per span — same work
    let mut probed = SimulationBuilder::from_config(cfg(2)).build().expect("construction");
    let mut counts = SpikeCountProbe::new();
    {
        let mut session = probed.session();
        session.attach(&mut counts);
        session.advance(30.0);
    }
    assert_eq!(counts.total(), expected);
    assert_eq!(probed.summary().spikes(), expected);
}

#[test]
fn rank_panic_surfaces_payload_and_poisons_the_session() {
    // fault injection: rank 1 panics at step 5, mid-collectives — the
    // executor must propagate the payload (not deadlock) and refuse
    // further stepping
    let opts = RunOptions { fault: Some(FaultPlan::panic_at(1, 5)), ..Default::default() };
    let mut net =
        SimulationBuilder::from_parts(cfg(2), opts).build().expect("construction");
    let result = catch_unwind(AssertUnwindSafe(|| {
        net.session().advance(20.0);
    }));
    let payload = result.expect_err("rank panic must propagate to the caller");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .expect("panic payload should be the executor's message");
    assert!(msg.contains("injected fault"), "payload lost: {msg}");
    assert!(msg.contains("rank 1"), "rank attribution lost: {msg}");

    // poisoned: try_advance reports the root cause instead of running
    let err = net.session().try_advance(1.0).unwrap_err();
    assert!(err.contains("poisoned"), "{err}");
    assert!(err.contains("injected fault"), "root cause lost: {err}");
    assert_eq!(net.poison_message().map(|m| m.contains("injected fault")), Some(true));

    // reporting still works on the poisoned wreck, and drop is clean
    let summary = net.summary();
    assert_eq!(summary.ranks, 2);
    drop(net);
}

#[test]
fn drop_without_shutdown_terminates_cleanly() {
    // no explicit shutdown call anywhere: Drop must stop the workers
    // (a leak or deadlock here would hang the test binary)
    for _ in 0..3 {
        let mut net =
            SimulationBuilder::from_config(cfg(2)).build().expect("construction");
        net.session().advance(5.0);
        assert!(net.summary().spikes() > 0);
        drop(net);
    }
    // an abandoned-but-never-stepped pool must also shut down
    let net = SimulationBuilder::from_config(cfg(4)).build().expect("construction");
    drop(net);
}
