//! Integration: the staged build-once/run-many seam.
//!
//! * One constructed `Network` driven for 2×50 ms produces bit-identical
//!   spikes (totals AND the full per-step per-column activity) to a
//!   fresh 100 ms run — and to the legacy one-shot `run_simulation`.
//! * The kernel-trait Gaussian/exponential built-ins match the old
//!   enum's `prob_at` across the stencil radius.
//! * Reset + stimulus reseeding reuse the construction.

// Cast clippy lints are package-wide warnings (Cargo.toml [lints]);
// the boundary modules are enforced by `dpsnn lint` (docs/LINTS.md).
#![allow(clippy::cast_possible_truncation)]
#![allow(clippy::cast_sign_loss)]
#![allow(clippy::cast_possible_wrap)]

// the deprecated one-shot wrapper is exercised deliberately: it must
// keep matching the staged pipeline
#![allow(deprecated)]

use dpsnn::config::{ConnParams, SimConfig};
use dpsnn::connectivity::{builtin_kernel, Stencil};
use dpsnn::coordinator::run_simulation;
use dpsnn::engine::RunOptions;
use dpsnn::geometry::Grid;
use dpsnn::{ActivityProbe, SimulationBuilder};

fn cfg() -> SimConfig {
    let mut c = SimConfig::test_small();
    c.duration_ms = 100.0;
    c.external.synapses_per_neuron = 100;
    c.external.rate_hz = 30.0;
    c.ranks = 2;
    c
}

/// Drive `chunks` sessions of `ms` each against one network and return
/// (total spikes, full activity matrix).
fn staged_run(chunks: &[f64]) -> (u64, Vec<Vec<u32>>) {
    let mut net = SimulationBuilder::from_config(cfg()).build().expect("construction");
    let mut activity = ActivityProbe::new();
    for &ms in chunks {
        let mut session = net.session();
        session.attach(&mut activity);
        session.advance(ms);
    }
    (net.summary().spikes(), activity.into_rows())
}

#[test]
fn two_half_sessions_equal_one_full_run() {
    let (split_spikes, split_activity) = staged_run(&[50.0, 50.0]);
    let (whole_spikes, whole_activity) = staged_run(&[100.0]);
    assert!(split_spikes > 0);
    assert_eq!(split_spikes, whole_spikes, "2x50 ms must equal 100 ms");
    assert_eq!(split_activity.len(), 100);
    assert_eq!(
        split_activity, whole_activity,
        "per-step per-column activity must be bit-identical across the session split"
    );
}

#[test]
fn wrapper_matches_staged_pipeline() {
    // run_simulation is now a thin wrapper over the staged API; its
    // summary must agree with a hand-driven network
    let opts = RunOptions { record_activity: true, ..Default::default() };
    let s = run_simulation(&cfg(), &opts);
    let (spikes, activity) = staged_run(&[100.0]);
    assert_eq!(s.spikes(), spikes);
    assert_eq!(s.activity, activity);
    assert_eq!(s.duration_ms, 100.0);
    assert_eq!(s.reports.len(), 2);
    let total: u64 = s.activity.iter().flat_map(|r| r.iter().map(|&n| n as u64)).sum();
    assert_eq!(total, s.spikes());
}

#[test]
fn kernel_trait_matches_legacy_enum_across_stencil_radius() {
    for conn in [ConnParams::gaussian(), ConnParams::exponential()] {
        let kernel = builtin_kernel(conn.rule.name(), &conn).expect("registered");
        // sample densely across (and beyond) the stencil reach
        let grid = Grid::new(cfg().grid);
        let radius = kernel.stencil_radius(&grid, conn.cutoff);
        let max_r = (radius as f64 + 2.0) * grid.p.spacing_um;
        let mut r = 0.0;
        while r <= max_r {
            assert_eq!(
                kernel.prob_at(r).to_bits(),
                conn.prob_at(r).to_bits(),
                "{} kernel diverges from enum at r = {r} um",
                conn.rule.name()
            );
            r += 7.3;
        }
        // and the stencils they induce are identical
        let legacy = Stencil::remote(&conn, &grid);
        let traited = Stencil::for_kernel(&*kernel, conn.cutoff, &grid);
        assert_eq!(legacy.bbox_side, traited.bbox_side);
        assert_eq!(legacy.offsets.len(), traited.offsets.len());
    }
}

#[test]
fn reset_and_stimulus_sweep_share_one_construction() {
    let mut net = SimulationBuilder::from_config(cfg()).build().expect("construction");
    let synapses = net.summary().synapses();
    net.session().advance(50.0);
    let base = net.summary().spikes();
    assert!(base > 0);

    // reset → bit-identical replay
    net.reset();
    net.session().advance(50.0);
    assert_eq!(net.summary().spikes(), base);

    // reseed the stimulus → different activity, same construction
    net.reset();
    net.set_external(100, 90.0);
    net.session().advance(50.0);
    let hot = net.summary().spikes();
    assert!(hot > base, "3x stimulus must raise activity ({base} -> {hot})");
    assert_eq!(net.summary().synapses(), synapses, "construction must be untouched");
}

#[test]
fn custom_kernel_runs_end_to_end_and_respects_its_stencil() {
    // a flat-disc network constructs through the same machinery and
    // stays inside its disc-derived stencil
    let mut b = SimulationBuilder::from_config(cfg());
    b = b.kernel_named("flat-disc").expect("registered kernel");
    let kernel_name = b.config().kernel_name();
    assert_eq!(kernel_name, "flat-disc");
    let mut net = b.build().expect("construction");
    net.session().advance(30.0);
    let s = net.summary();
    assert!(s.spikes() > 0, "flat-disc network must be active");
    assert!(s.synapses() > 0);
}
