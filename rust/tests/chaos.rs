//! Integration: the fault-injection matrix against the crash-recovering
//! executor.
//!
//! * A rank panic at **every** step phase, across 1/2/4 ranks, recovers
//!   from the auto-checkpoint and finishes bit-identically to the
//!   unfaulted run.
//! * A hung rank is diagnosed by the watchdog (poisoning that names the
//!   stuck rank) — and recovered from when checkpointing is armed.
//! * An unrecoverable fault exhausts the retry budget and surfaces the
//!   *original* panic payload, with the give-up counted.
//! * A delayed reply below the watchdog deadline is benign.
//! * The same matrix holds on the **shm transport** (forked worker
//!   processes): a worker process that panics, hangs, or plain *dies*
//!   (`FaultMode::Die` — `_exit` mid-step, no reply, no ring close) is
//!   diagnosed by name and recovered from bit-identically.

use std::panic::{catch_unwind, AssertUnwindSafe};

use dpsnn::config::SimConfig;
use dpsnn::engine::{FaultMode, FaultPhase, FaultPlan, RunOptions};
use dpsnn::{ActivityProbe, Network, RecoveryStats, SimulationBuilder, TransportKind};

fn cfg(ranks: u32) -> SimConfig {
    let mut c = SimConfig::test_small();
    c.external.synapses_per_neuron = 100;
    c.external.rate_hz = 30.0;
    c.ranks = ranks;
    c
}

/// Options with crash recovery armed: checkpoint every 8 steps, no
/// backoff sleeps (the matrix re-runs many recoveries).
fn opts_recovering(fault: Option<FaultPlan>) -> RunOptions {
    RunOptions {
        fault,
        checkpoint_every_steps: Some(8),
        recovery_backoff_ms: 0,
        ..Default::default()
    }
}

fn build(ranks: u32, opts: RunOptions) -> Network {
    build_t(ranks, opts, TransportKind::Channel)
}

/// [`build`] with an explicit transport (explicit config wins over a
/// CI-forced `DPSNN_TRANSPORT`, so the channel tests stay meaningful).
fn build_t(ranks: u32, opts: RunOptions, transport: TransportKind) -> Network {
    SimulationBuilder::from_parts(cfg(ranks), opts)
        .transport(transport)
        .build()
        .expect("construction")
}

/// Advance `ms` recording per-step global column activity.
fn run_recorded(net: &mut Network, ms: f64) -> Vec<Vec<u32>> {
    let mut activity = ActivityProbe::new();
    {
        let mut session = net.session();
        session.attach(&mut activity);
        session.advance(ms);
    }
    activity.into_rows()
}

#[test]
fn panic_at_every_phase_recovers_bit_identically() {
    let phases = [
        FaultPhase::StepStart,
        FaultPhase::AfterPack,
        FaultPhase::AfterExchange,
        FaultPhase::AfterDemux,
        FaultPhase::StepEnd,
    ];
    for ranks in [1u32, 2, 4] {
        let reference = run_recorded(&mut build(ranks, opts_recovering(None)), 30.0);
        assert!(
            reference.iter().flatten().any(|&n| n > 0),
            "reference must be active at {ranks} ranks"
        );
        for phase in phases {
            let fault = FaultPlan {
                rank: ranks - 1,
                step: 5,
                phase,
                mode: FaultMode::Panic,
                max_fires: 1,
            };
            let mut net = build(ranks, opts_recovering(Some(fault)));
            let rows = run_recorded(&mut net, 30.0);
            assert_eq!(
                rows, reference,
                "recovered run diverged ({ranks} ranks, fault at {phase:?})"
            );
            let stats = net.recovery_stats();
            assert!(
                stats.recoveries >= 1,
                "no recovery recorded ({ranks} ranks, {phase:?}): {stats:?}"
            );
            assert_eq!(stats.giveups, 0, "({ranks} ranks, {phase:?})");
            assert!(net.poison_message().is_none(), "network left poisoned");
        }
    }
}

#[test]
fn hung_rank_is_diagnosed_by_the_watchdog() {
    // recovery NOT armed: the watchdog poisoning is terminal and must
    // name the silent rank instead of deadlocking the collect
    let opts = RunOptions {
        fault: Some(FaultPlan::hang_at(1, 3)),
        watchdog_timeout_ms: Some(400),
        ..Default::default()
    };
    let mut net = build(2, opts);
    let result = catch_unwind(AssertUnwindSafe(|| {
        net.session().advance(10.0);
    }));
    let payload = result.expect_err("a hung rank must poison, not deadlock");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .expect("panic payload should be the executor's message");
    assert!(msg.contains("watchdog"), "{msg}");
    assert!(msg.contains("rank 1"), "stuck rank not named: {msg}");

    // poisoned thereafter, with the diagnosis preserved
    let err = net.session().try_advance(1.0).unwrap_err();
    assert!(err.contains("watchdog"), "{err}");
    // dropping the network must not block on the parked worker
    drop(net);
}

#[test]
fn hung_rank_recovers_when_checkpointing_is_armed() {
    let reference = run_recorded(&mut build(2, opts_recovering(None)), 20.0);
    let mut opts = opts_recovering(Some(FaultPlan::hang_at(1, 5)));
    opts.watchdog_timeout_ms = Some(400);
    let mut net = build(2, opts);
    let rows = run_recorded(&mut net, 20.0);
    assert_eq!(rows, reference, "post-recovery run diverged");
    assert!(net.recovery_stats().recoveries >= 1);
    assert_eq!(net.recovery_stats().giveups, 0);
}

#[test]
fn retry_exhaustion_preserves_the_original_fault_payload() {
    // a fault that re-fires on every attempt is unrecoverable: the
    // budget must bound the retries and the FIRST error must surface
    let fault = FaultPlan {
        rank: 0,
        step: 2,
        phase: FaultPhase::StepStart,
        mode: FaultMode::Panic,
        max_fires: u32::MAX,
    };
    let mut opts = opts_recovering(Some(fault));
    opts.recovery_retries = 2;
    let mut net = build(2, opts);
    let result = catch_unwind(AssertUnwindSafe(|| {
        net.session().advance(10.0);
    }));
    let payload = result.expect_err("exhausted retries must surface the fault");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .expect("panic payload should be the executor's message");
    assert!(msg.contains("injected fault"), "original payload lost: {msg}");
    assert!(msg.contains("rank 0"), "rank attribution lost: {msg}");
    let stats = net.recovery_stats();
    assert_eq!(stats.giveups, 1, "{stats:?}");
    assert_eq!(stats.retries_spent, 2, "{stats:?}");
    assert!(net.poison_message().is_some(), "exhaustion must leave the poison visible");
}

#[test]
fn die_fault_recovers_bit_identically_on_both_backends() {
    // a worker that VANISHES mid-step (no panic reply, no clean ring
    // close) on either backend: the pool diagnoses it (watchdog on
    // threads, waitpid on processes), rebuilds, and replays from the
    // auto-checkpoint to the exact unfaulted spike train
    let reference = run_recorded(&mut build(2, opts_recovering(None)), 30.0);
    assert!(reference.iter().flatten().any(|&n| n > 0), "reference must be active");
    for transport in [TransportKind::Channel, TransportKind::Shm] {
        let fault = FaultPlan {
            rank: 1,
            step: 5,
            phase: FaultPhase::AfterPack,
            mode: FaultMode::Die,
            max_fires: 1,
        };
        let mut opts = opts_recovering(Some(fault));
        // the thread backend can only notice a silent worker through
        // the watchdog; the proc backend reaps it via waitpid first
        opts.watchdog_timeout_ms = Some(400);
        let mut net = build_t(2, opts, transport);
        let rows = run_recorded(&mut net, 30.0);
        assert_eq!(rows, reference, "post-death recovery diverged over {transport:?}");
        assert!(
            net.recovery_stats().recoveries >= 1,
            "no recovery recorded over {transport:?}"
        );
        assert_eq!(net.recovery_stats().giveups, 0, "over {transport:?}");
        assert!(net.poison_message().is_none(), "left poisoned over {transport:?}");
    }
}

#[test]
fn died_shm_worker_is_named_by_the_parent() {
    // recovery NOT armed: the waitpid diagnosis is terminal and must
    // name the dead rank — not the "hung up" cascade its peers raise
    let fault = FaultPlan {
        rank: 1,
        step: 3,
        phase: FaultPhase::AfterPack,
        mode: FaultMode::Die,
        max_fires: 1,
    };
    let opts = RunOptions { fault: Some(fault), ..Default::default() };
    let mut net = build_t(2, opts, TransportKind::Shm);
    let result = catch_unwind(AssertUnwindSafe(|| {
        net.session().advance(10.0);
    }));
    let payload = result.expect_err("a dead worker process must poison the session");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .expect("panic payload should be the executor's message");
    assert!(msg.contains("rank 1 worker process"), "dead rank not named: {msg}");
    assert!(!msg.contains("hung up"), "cascade masked the real diagnosis: {msg}");
    drop(net);
}

#[test]
fn panic_at_every_phase_recovers_bit_identically_over_shm() {
    // the thread-backend matrix above, on forked worker processes: the
    // panic travels back through the reply ring, recovery re-forks from
    // pristine construction state and restores the auto-checkpoint
    let reference = run_recorded(
        &mut build_t(2, opts_recovering(None), TransportKind::Shm),
        30.0,
    );
    for phase in [FaultPhase::StepStart, FaultPhase::AfterExchange, FaultPhase::StepEnd] {
        let fault = FaultPlan { rank: 1, step: 5, phase, mode: FaultMode::Panic, max_fires: 1 };
        let mut net = build_t(2, opts_recovering(Some(fault)), TransportKind::Shm);
        let rows = run_recorded(&mut net, 30.0);
        assert_eq!(rows, reference, "shm recovery diverged (fault at {phase:?})");
        assert!(net.recovery_stats().recoveries >= 1, "no shm recovery at {phase:?}");
        assert!(net.poison_message().is_none());
    }
}

#[test]
fn hung_shm_rank_is_diagnosed_by_the_watchdog() {
    let opts = RunOptions {
        fault: Some(FaultPlan::hang_at(1, 3)),
        watchdog_timeout_ms: Some(400),
        ..Default::default()
    };
    let mut net = build_t(2, opts, TransportKind::Shm);
    let result = catch_unwind(AssertUnwindSafe(|| {
        net.session().advance(10.0);
    }));
    let payload = result.expect_err("a hung shm worker must poison, not deadlock");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .expect("panic payload should be the executor's message");
    assert!(msg.contains("watchdog"), "{msg}");
    assert!(msg.contains("rank 1"), "stuck rank not named: {msg}");
    // dropping the poisoned network must kill + reap the stuck child
    drop(net);
}

#[test]
fn delayed_reply_below_the_watchdog_deadline_is_benign() {
    let reference = run_recorded(&mut build(2, RunOptions::default()), 20.0);
    let fault = FaultPlan {
        rank: 1,
        step: 4,
        phase: FaultPhase::StepEnd,
        mode: FaultMode::DelayReplyMs(100),
        max_fires: 1,
    };
    let opts = RunOptions {
        fault: Some(fault),
        watchdog_timeout_ms: Some(5_000),
        ..Default::default()
    };
    let mut net = build(2, opts);
    let rows = run_recorded(&mut net, 20.0);
    assert_eq!(rows, reference, "a delayed reply must not change the dynamics");
    assert_eq!(net.recovery_stats(), RecoveryStats::default());
    assert!(net.poison_message().is_none());
}
