"""AOT path: lowering to HLO text must be deterministic, structurally
sound, and shaped exactly as the Rust runtime expects."""

import pathlib
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def lif_text():
    return aot.lower_lif_step(1024)


class TestLowering:
    def test_hlo_text_has_entry_and_tuple_output(self, lif_text):
        assert "ENTRY" in lif_text
        assert "HloModule" in lif_text
        # 4-tuple output (v, c, refr, spike)
        assert re.search(r"\(f32\[1024\]?.*f32\[1024\]", lif_text.replace("\n", " "))

    def test_parameter_count_matches_batch_solver(self, lif_text):
        # 8 array inputs + 5 scalars = 13 parameters (rust batch.rs order)
        params = re.findall(r"parameter\(\d+\)", lif_text)
        assert len(set(params)) == 13, sorted(set(params))

    def test_lowering_is_deterministic(self):
        a = aot.lower_lif_step(1024)
        b = aot.lower_lif_step(1024)
        assert a == b

    def test_batch_sizes_produce_right_shapes(self):
        text = aot.lower_lif_step(4096)
        assert "f32[4096]" in text
        assert "f32[1024]" not in text.replace("f32[1024]{0}", "")  # no stray

    def test_no_custom_calls_in_interpret_mode(self, lif_text):
        """interpret=True must lower to plain HLO (a Mosaic custom-call
        would make the artifact unloadable on the CPU PJRT client)."""
        assert "custom-call" not in lif_text or "mosaic" not in lif_text.lower()

    def test_conn_field_lowerings_differ_by_rule(self):
        g = aot.lower_conn("gaussian", 1024)
        e = aot.lower_conn("exponential", 1024)
        assert g != e
        for text in (g, e):
            assert "ENTRY" in text

    def test_scan_artifact_has_time_major_input(self):
        t, n = aot.SCAN_SHAPE
        text = aot.lower_lif_scan(t, n)
        assert f"f32[{t},{n}]" in text


class TestBuildAll:
    def test_build_all_writes_manifest_consistent_artifacts(self, tmp_path):
        arts = aot.build_all(pathlib.Path(tmp_path), verbose=False)
        # every batch size + scan + two conn fields
        assert len(arts) == len(aot.BATCH_SIZES) + 3
        for name in arts:
            p = pathlib.Path(tmp_path) / f"{name}.hlo.txt"
            assert p.exists() and p.stat().st_size > 1000, name

    def test_manifest_matches_repo_artifacts_if_built(self):
        """If `make artifacts` has run, the checked-in manifest must match
        a fresh lowering (catches kernel/artifact drift)."""
        repo_arts = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
        manifest = repo_arts / "MANIFEST.txt"
        if not manifest.exists():
            pytest.skip("artifacts not built")
        lines = dict(l.split() for l in manifest.read_text().splitlines())
        import hashlib
        fresh = aot.lower_lif_step(1024)
        digest = hashlib.sha256(fresh.encode()).hexdigest()[:16]
        assert lines.get("lif_step_1024") == digest, \
            "artifacts stale: run `make artifacts`"
