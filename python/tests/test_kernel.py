"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The CORE correctness signal of the compile path: hypothesis sweeps
state/parameter space and the kernels must match ref.py everywhere.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import conn_prob as conn_mod
from compile.kernels import lif_step as lif_mod
from compile.kernels.ref import conn_prob_ref, lif_step_ref

N = lif_mod.BLOCK  # one tile


def _mk_state(rng, n=N):
    return dict(
        v=jnp.array(rng.uniform(-80, -45, n), jnp.float32),
        c=jnp.array(rng.uniform(0, 10, n), jnp.float32),
        refr=jnp.array(rng.choice([0.0, 0.5, 1.5, 2.0], n), jnp.float32),
        j=jnp.array(rng.normal(0, 5, n), jnp.float32),
    )


def _mk_consts(tau_m=20.0, tau_c=300.0, g=0.02, dt=1.0, n=N):
    em = float(np.exp(-dt / tau_m))
    ec = float(np.exp(-dt / tau_c))
    kf = g / (1.0 / tau_m - 1.0 / tau_c)
    return dict(
        em=jnp.full(n, em, jnp.float32),
        ec=jnp.full(n, ec, jnp.float32),
        kf=jnp.full(n, kf, jnp.float32),
        alpha=jnp.full(n, 1.0, jnp.float32),
    )


SCALARS = dict(
    e_rest=jnp.float32(-65.0),
    v_theta=jnp.float32(-50.0),
    v_reset=jnp.float32(-60.0),
    tau_arp=jnp.float32(2.0),
    dt=jnp.float32(1.0),
)


def run_both(state, consts, scalars=SCALARS):
    args = (state["v"], state["c"], state["refr"], state["j"],
            consts["em"], consts["ec"], consts["kf"], consts["alpha"],
            scalars["e_rest"], scalars["v_theta"], scalars["v_reset"],
            scalars["tau_arp"], scalars["dt"])
    return lif_mod.lif_step(*args), lif_step_ref(*args)


class TestLifKernelVsRef:
    def test_random_state_matches_ref(self):
        rng = np.random.default_rng(42)
        kern, ref = run_both(_mk_state(rng), _mk_consts())
        for a, b, name in zip(kern, ref, ("v", "c", "refr", "spike")):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6,
                                       err_msg=name)

    @pytest.mark.parametrize("n", [1024, 2048, 4096, 16384])
    def test_multiple_batch_sizes(self, n):
        rng = np.random.default_rng(n)
        kern, ref = run_both(_mk_state(rng, n), _mk_consts(n=n))
        np.testing.assert_allclose(kern[0], ref[0], rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(kern[3]), np.asarray(ref[3]))

    def test_non_multiple_of_block_rejected(self):
        rng = np.random.default_rng(0)
        with pytest.raises(AssertionError):
            run_both(_mk_state(rng, 1000), _mk_consts(n=1000))

    @settings(max_examples=40, deadline=None)
    @given(
        tau_m=st.floats(2.0, 100.0),
        tau_c=st.floats(2.0, 2000.0),
        g=st.floats(0.0, 1.0),
        dt=st.floats(0.1, 5.0),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_sweep_params(self, tau_m, tau_c, g, dt, seed):
        # kf = g/(1/tau_m - 1/tau_c) blows up as tau_m -> tau_c and the
        # f32 closed form loses precision to cancellation (the engine's
        # f64 event-driven path and the exact-degenerate branch handle
        # it); skip the near-singular band where kf > ~1e3
        if abs(1.0 / tau_m - 1.0 / tau_c) < 1e-3:
            return
        rng = np.random.default_rng(seed)
        scal = dict(SCALARS)
        scal["dt"] = jnp.float32(dt)
        kern, ref = run_both(_mk_state(rng), _mk_consts(tau_m, tau_c, g, dt),
                             scal)
        for a, b, name in zip(kern, ref, ("v", "c", "refr", "spike")):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5,
                                       err_msg=name)


class TestLifPhysics:
    """Physical invariants, independent of the oracle."""

    def test_resting_neuron_stays_at_rest(self):
        n = N
        z = jnp.zeros(n, jnp.float32)
        v = jnp.full(n, -65.0, jnp.float32)
        out = lif_mod.lif_step(v, z, z, z, *(_mk_consts().values()),
                               *SCALARS.values())
        np.testing.assert_allclose(out[0], -65.0, atol=1e-5)
        assert float(out[3].sum()) == 0

    def test_suprathreshold_jump_spikes_and_resets(self):
        n = N
        z = jnp.zeros(n, jnp.float32)
        v = jnp.full(n, -65.0, jnp.float32)
        j = jnp.full(n, 100.0, jnp.float32)
        c = _mk_consts()
        out = lif_mod.lif_step(v, z, z, j, *c.values(), *SCALARS.values())
        assert float(out[3].sum()) == n, "all neurons must spike"
        # reset to -60 then one dt of decay toward E with fatigue pull
        assert np.all(np.asarray(out[0]) < -59.0)
        # fatigue incremented then decayed one step
        np.testing.assert_allclose(out[1], float(c["ec"][0]), rtol=1e-5)
        # refractory reloaded
        np.testing.assert_allclose(out[2], 2.0, atol=1e-6)

    def test_refractory_neurons_ignore_input(self):
        n = N
        z = jnp.zeros(n, jnp.float32)
        v = jnp.full(n, -65.0, jnp.float32)
        refr = jnp.full(n, 1.5, jnp.float32)
        j = jnp.full(n, 100.0, jnp.float32)
        out = lif_mod.lif_step(v, z, refr, j, *(_mk_consts().values()),
                               *SCALARS.values())
        assert float(out[3].sum()) == 0
        np.testing.assert_allclose(out[2], 0.5, atol=1e-6)

    def test_fatigue_pulls_potential_down(self):
        n = N
        z = jnp.zeros(n, jnp.float32)
        v = jnp.full(n, -55.0, jnp.float32)
        c_hi = jnp.full(n, 10.0, jnp.float32)
        consts = _mk_consts()
        out_no_c = lif_mod.lif_step(v, z, z, z, *consts.values(),
                                    *SCALARS.values())
        out_hi_c = lif_mod.lif_step(v, c_hi, z, z, *consts.values(),
                                    *SCALARS.values())
        assert np.all(np.asarray(out_hi_c[0]) < np.asarray(out_no_c[0])), \
            "adaptation current must hyperpolarize"

    def test_spike_count_monotone_in_drive(self):
        rng = np.random.default_rng(7)
        state = _mk_state(rng)
        consts = _mk_consts()
        counts = []
        for scale in (0.0, 2.0, 8.0):
            s = dict(state)
            s["j"] = state["j"] * 0 + scale
            out, _ = run_both(s, consts)
            counts.append(float(out[0][3].sum()) if isinstance(out, tuple) and len(out) == 1 else float(out[3].sum()))
        assert counts[0] <= counts[1] <= counts[2]


class TestConnKernelVsRef:
    @pytest.mark.parametrize("rule,amp,scale", [
        ("gaussian", 0.05, 100.0),
        ("exponential", 0.03, 290.0),
    ])
    def test_matches_ref(self, rule, amp, scale):
        n = conn_mod.BLOCK
        rng = np.random.default_rng(3)
        dx = jnp.array(rng.integers(-12, 13, n), jnp.float32)
        dy = jnp.array(rng.integers(-12, 13, n), jnp.float32)
        args = (dx, dy, jnp.float32(amp), jnp.float32(scale),
                jnp.float32(100.0), jnp.float32(1e-3))
        kern = conn_mod.conn_prob(*args, rule=rule)
        ref = conn_prob_ref(dx, dy, *args[2:], rule=rule)
        for a, b, name in zip(kern, ref, ("p_center", "p_min", "mask")):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7,
                                       err_msg=name)

    def test_stencil_sizes_match_paper(self):
        """The cutoff mask must reproduce Fig. 2: 7x7 gaussian, 21x21 exp."""
        n = conn_mod.BLOCK
        coords = [(dx, dy) for dy in range(-15, 16) for dx in range(-15, 16)]
        pad = n - len(coords)
        dx = jnp.array([c[0] for c in coords] + [0] * pad, jnp.float32)
        dy = jnp.array([c[1] for c in coords] + [0] * pad, jnp.float32)
        for rule, amp, scale, expect in (
            ("gaussian", 0.05, 100.0, 3),
            ("exponential", 0.03, 290.0, 10),
        ):
            _, _, mask = conn_mod.conn_prob(
                dx, dy, jnp.float32(amp), jnp.float32(scale),
                jnp.float32(100.0), jnp.float32(1e-3), rule=rule)
            m = np.asarray(mask[:len(coords)]).reshape(31, 31)
            ys, xs = np.nonzero(m)
            reach = max(abs(xs - 15).max(), abs(ys - 15).max())
            assert reach == expect, f"{rule}: reach {reach} != {expect}"

    def test_bad_rule_rejected(self):
        n = conn_mod.BLOCK
        z = jnp.zeros(n, jnp.float32)
        with pytest.raises(AssertionError):
            conn_mod.conn_prob(z, z, jnp.float32(1), jnp.float32(1),
                               jnp.float32(1), jnp.float32(1), rule="nope")
