"""L2 correctness: the scan model composes the L1 kernel faithfully, and
the constants helper matches the Rust LifParams precomputation."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model

N = 1024
T = 7

SCALARS = (jnp.float32(-65.0), jnp.float32(-50.0), jnp.float32(-60.0),
           jnp.float32(2.0), jnp.float32(1.0))


def consts(n=N):
    em, ec, kf = model.neuron_constants(20.0, 300.0, 0.02, 1.0)
    return (jnp.full(n, em, jnp.float32), jnp.full(n, ec, jnp.float32),
            jnp.full(n, kf, jnp.float32), jnp.full(n, 1.0, jnp.float32))


class TestScanModel:
    def test_scan_equals_repeated_single_steps(self):
        rng = np.random.default_rng(5)
        v = jnp.array(rng.uniform(-70, -52, N), jnp.float32)
        c = jnp.zeros(N, jnp.float32)
        refr = jnp.zeros(N, jnp.float32)
        j_seq = jnp.array(rng.normal(0.5, 2.0, (T, N)), jnp.float32)
        cs = consts()

        sv, sc, srefr, spikes = model.lif_scan(v, c, refr, j_seq, *cs, *SCALARS)

        ev, ec_, erefr = v, c, refr
        manual_spikes = []
        for t in range(T):
            ev, ec_, erefr, sp = model.lif_step(ev, ec_, erefr, j_seq[t],
                                                *cs, *SCALARS)
            manual_spikes.append(sp)
        np.testing.assert_allclose(sv, ev, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(sc, ec_, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(srefr, erefr, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(spikes),
                                      np.stack(manual_spikes))

    def test_spike_raster_shape_and_range(self):
        v = jnp.full(N, -65.0, jnp.float32)
        z = jnp.zeros(N, jnp.float32)
        j_seq = jnp.full((T, N), 20.0, jnp.float32)  # strong periodic drive
        _, _, _, spikes = model.lif_scan(v, z, z, j_seq, *consts(), *SCALARS)
        assert spikes.shape == (T, N)
        s = np.asarray(spikes)
        assert set(np.unique(s)).issubset({0.0, 1.0})
        # first step must spike everywhere; the next one is refractory
        assert s[0].sum() == N
        assert s[1].sum() == 0


class TestNeuronConstants:
    @settings(max_examples=50, deadline=None)
    @given(tau_m=st.floats(1.0, 100.0), tau_c=st.floats(1.0, 2000.0),
           g=st.floats(0.0, 2.0), dt=st.floats(0.1, 5.0))
    def test_matches_rust_lifparams_algebra(self, tau_m, tau_c, g, dt):
        em, ec, kf = model.neuron_constants(tau_m, tau_c, g, dt)
        assert abs(float(em) - np.exp(-dt / tau_m)) < 1e-6
        assert abs(float(ec) - np.exp(-dt / tau_c)) < 1e-6
        denom = 1.0 / tau_m - 1.0 / tau_c
        if abs(denom) >= 1e-12:
            assert np.isclose(float(kf), g / denom, rtol=1e-6)

    def test_degenerate_taus_give_zero_coupling(self):
        _, _, kf = model.neuron_constants(20.0, 20.0, 0.5, 1.0)
        assert float(kf) == 0.0

    def test_decay_matches_closed_form_over_many_steps(self):
        """Chaining K steps of the step kernel must equal the closed-form
        exponential solution at time K*dt (the same algebra the Rust
        event-driven integrator uses between events)."""
        tau_m, tau_c, g, dt, k = 20.0, 300.0, 0.02, 1.0, 25
        cs = consts()
        v0, c0 = -55.0, 4.0
        v = jnp.full(N, v0, jnp.float32)
        c = jnp.full(N, c0, jnp.float32)
        z = jnp.zeros(N, jnp.float32)
        j_seq = jnp.zeros((k, N), jnp.float32)
        sv, sc, _, _ = model.lif_scan(v, c, z, j_seq, *cs, *SCALARS)
        t = k * dt
        e_rest = -65.0
        kk = -(g / (1.0 / tau_m - 1.0 / tau_c)) * c0
        v_exact = (e_rest + (v0 - e_rest - kk) * np.exp(-t / tau_m)
                   + kk * np.exp(-t / tau_c))
        c_exact = c0 * np.exp(-t / tau_c)
        np.testing.assert_allclose(float(sv[0]), v_exact, rtol=1e-4)
        np.testing.assert_allclose(float(sc[0]), c_exact, rtol=1e-4)
