"""L1 Pallas kernel: connection-probability stencil field (paper Fig. 2).

Evaluates, for a flat batch of column offsets (dx, dy), the remote
connection probability at the center distance, the best-case (minimum
possible) distance used by the 1/1000 cutoff, and the cutoff mask. The
Rust `connectivity_map` example executes the AOT artifact of this kernel
through PJRT to regenerate the Fig. 2 stencils.

Element-wise like lif_step, same BLOCK tiling; the rule (gaussian vs
exponential) is a lowering-time constant, so two artifacts are emitted.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _conn_kernel(rule, dx_ref, dy_ref, amp_ref, scale_ref, spacing_ref,
                 cutoff_ref, pc_out, pm_out, mask_out):
    dx = dx_ref[...]
    dy = dy_ref[...]
    amp = amp_ref[0]
    scale = scale_ref[0]
    spacing = spacing_ref[0]
    cutoff = cutoff_ref[0]

    r_center = spacing * jnp.sqrt(dx * dx + dy * dy)
    gx = jnp.maximum(jnp.abs(dx) - 1.0, 0.0)
    gy = jnp.maximum(jnp.abs(dy) - 1.0, 0.0)
    r_min = spacing * jnp.sqrt(gx * gx + gy * gy)

    if rule == "gaussian":
        p_center = amp * jnp.exp(-(r_center * r_center) / (2.0 * scale * scale))
        p_min = amp * jnp.exp(-(r_min * r_min) / (2.0 * scale * scale))
    else:
        p_center = amp * jnp.exp(-r_center / scale)
        p_min = amp * jnp.exp(-r_min / scale)

    is_self = jnp.logical_and(dx == 0.0, dy == 0.0)
    mask = jnp.logical_and(p_min > cutoff, jnp.logical_not(is_self))
    pc_out[...] = p_center
    pm_out[...] = p_min
    mask_out[...] = mask.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("rule",))
def conn_prob(dx, dy, amplitude, scale_um, spacing_um, cutoff, *, rule):
    """Probability field for offsets (dx, dy); rule in {gaussian, exponential}.

    dx, dy are f32[N] with N a multiple of BLOCK; scalars f32.
    Returns (p_center, p_min, mask).
    """
    assert rule in ("gaussian", "exponential"), rule
    n = dx.shape[0]
    assert n % BLOCK == 0, f"batch {n} not a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    tile = pl.BlockSpec((BLOCK,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32) for _ in range(3)]
    return tuple(
        pl.pallas_call(
            functools.partial(_conn_kernel, rule),
            grid=grid,
            in_specs=[tile] * 2 + [scalar] * 4,
            out_specs=[tile] * 3,
            out_shape=out_shape,
            interpret=True,
        )(
            dx, dy,
            jnp.reshape(amplitude, (1,)).astype(jnp.float32),
            jnp.reshape(scale_um, (1,)).astype(jnp.float32),
            jnp.reshape(spacing_um, (1,)).astype(jnp.float32),
            jnp.reshape(cutoff, (1,)).astype(jnp.float32),
        )
    )
