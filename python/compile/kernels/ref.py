"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: the Pallas kernels must agree
with them to float tolerance (same primitive ops, same order), and the
Rust event-driven solver agrees with the same closed forms (see
rust/src/neuron/lif.rs -- identical exponential-integrator algebra).
"""

import jax.numpy as jnp


def lif_step_ref(v, c, refr, j, em, ec, kf, alpha, e_rest, v_theta, v_reset,
                 tau_arp, dt):
    """One time-driven LIF+SFA step (paper eqs. 1-2), batched.

    Semantics (mirrors rust/src/runtime/batch.rs):
      1. neurons still refractory discard this step's aggregated current,
      2. the surviving current is applied as one jump; threshold crossing
         emits a spike, resets V to ``v_reset`` and increments the
         fatigue variable by ``alpha``,
      3. (V, c) decay exactly over ``dt``:
           c' = c * ec,   ec = exp(-dt/tau_c)
           V' = E + (V - E - K) * em + K * ec,   K = -kf * c
         with per-neuron constants em = exp(-dt/tau_m) and
         kf = (g_c/C_m) / (1/tau_m - 1/tau_c),
      4. the refractory countdown advances (spikers reload tau_arp).

    All arrays are f32[N]; the five trailing parameters are f32 scalars.
    Returns (v', c', refr', spike) with spike as f32 0/1.
    """
    active = refr <= 0.0
    v_in = v + jnp.where(active, j, 0.0)
    spike = jnp.logical_and(active, v_in >= v_theta)
    v_post = jnp.where(spike, v_reset, v_in)
    c_post = c + jnp.where(spike, alpha, 0.0)
    k = -kf * c_post
    v_new = e_rest + (v_post - e_rest - k) * em + k * ec
    c_new = c_post * ec
    refr_new = jnp.where(spike, tau_arp, jnp.maximum(refr - dt, 0.0))
    return v_new, c_new, refr_new, spike.astype(jnp.float32)


def conn_prob_ref(dx, dy, amplitude, scale_um, spacing_um, cutoff, rule):
    """Connection-probability field over column offsets (paper Fig. 2).

    For each column offset (dx, dy) returns:
      * p_center -- probability at the center-to-center distance,
      * p_min    -- probability at the minimum possible neuron-to-neuron
                    distance (corner-to-corner best case used by the
                    1/1000 cutoff, which yields the paper's 7x7 / 21x21
                    stencils),
      * mask     -- 1.0 where the offset survives the cutoff.

    ``rule`` is "gaussian" (p = A exp(-r^2/2 sigma^2), scale_um = sigma)
    or "exponential" (p = A exp(-r/lambda), scale_um = lambda).
    """
    r_center = spacing_um * jnp.sqrt(dx * dx + dy * dy)
    gx = jnp.maximum(jnp.abs(dx) - 1.0, 0.0)
    gy = jnp.maximum(jnp.abs(dy) - 1.0, 0.0)
    r_min = spacing_um * jnp.sqrt(gx * gx + gy * gy)

    def p_of(r):
        if rule == "gaussian":
            return amplitude * jnp.exp(-(r * r) / (2.0 * scale_um * scale_um))
        if rule == "exponential":
            return amplitude * jnp.exp(-r / scale_um)
        raise ValueError(f"unknown rule {rule!r}")

    p_center = p_of(r_center)
    p_min = p_of(r_min)
    is_self = jnp.logical_and(dx == 0.0, dy == 0.0)
    mask = jnp.logical_and(p_min > cutoff, jnp.logical_not(is_self))
    return p_center, p_min, mask.astype(jnp.float32)
