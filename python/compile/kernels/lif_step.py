"""L1 Pallas kernel: batched LIF+SFA time-driven step.

The paper's compute hot-spot is the neuron-dynamics phase (Fig. 1 steps
2.4-2.6): every local neuron absorbs its step current and advances its
(V, c, refractory) state with exact exponential decay. This kernel
performs that update for a whole cluster of neurons in one shot.

TPU mapping (DESIGN.md "Hardware adaptation"): the update is purely
element-wise over five state/input arrays and three per-neuron constant
arrays -> VPU-bound, memory-bandwidth roofline. BlockSpec tiles the
neuron axis in BLOCK=1024-lane chunks (8 sublanes x 128 lanes), so each
grid step streams one VMEM-resident tile of every operand, and the whole
update fuses into a single pass (one HBM read + one write per array).
Scalars ride along as (1,)-blocks mapped to index 0 in every grid step.

Lowered with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode emits plain HLO with identical numerics
(validated against kernels/ref.py by python/tests/test_kernel.py, and
against the Rust event-driven integrator by rust/src/runtime/batch.rs
tests).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 sublanes x 128 lanes: one float32 VPU tile per block row.
BLOCK = 1024


def _lif_kernel(v_ref, c_ref, refr_ref, j_ref, em_ref, ec_ref, kf_ref,
                alpha_ref, e_ref, th_ref, vr_ref, ta_ref, dt_ref,
                v_out, c_out, refr_out, spike_out):
    """Element-wise LIF+SFA update of one BLOCK tile."""
    v = v_ref[...]
    c = c_ref[...]
    refr = refr_ref[...]
    j = j_ref[...]
    em = em_ref[...]
    ec = ec_ref[...]
    kf = kf_ref[...]
    alpha = alpha_ref[...]
    e_rest = e_ref[0]
    v_theta = th_ref[0]
    v_reset = vr_ref[0]
    tau_arp = ta_ref[0]
    dt = dt_ref[0]

    active = refr <= 0.0
    v_in = v + jnp.where(active, j, 0.0)
    spike = jnp.logical_and(active, v_in >= v_theta)
    v_post = jnp.where(spike, v_reset, v_in)
    c_post = c + jnp.where(spike, alpha, 0.0)
    k = -kf * c_post
    v_out[...] = e_rest + (v_post - e_rest - k) * em + k * ec
    c_out[...] = c_post * ec
    refr_out[...] = jnp.where(spike, tau_arp, jnp.maximum(refr - dt, 0.0))
    spike_out[...] = spike.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def lif_step(v, c, refr, j, em, ec, kf, alpha, e_rest, v_theta, v_reset,
             tau_arp, dt):
    """One dt step for N neurons (N must be a multiple of BLOCK).

    Array args are f32[N]; the five trailing args are f32 scalars.
    Returns (v', c', refr', spike) -- see kernels/ref.py for semantics.
    """
    n = v.shape[0]
    assert n % BLOCK == 0, f"batch {n} not a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    tile = pl.BlockSpec((BLOCK,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    out_shape = [
        jax.ShapeDtypeStruct((n,), jnp.float32),  # v'
        jax.ShapeDtypeStruct((n,), jnp.float32),  # c'
        jax.ShapeDtypeStruct((n,), jnp.float32),  # refr'
        jax.ShapeDtypeStruct((n,), jnp.float32),  # spike
    ]
    return tuple(
        pl.pallas_call(
            _lif_kernel,
            grid=grid,
            in_specs=[tile] * 8 + [scalar] * 5,
            out_specs=[tile] * 4,
            out_shape=out_shape,
            interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
        )(
            v, c, refr, j, em, ec, kf, alpha,
            jnp.reshape(e_rest, (1,)).astype(jnp.float32),
            jnp.reshape(v_theta, (1,)).astype(jnp.float32),
            jnp.reshape(v_reset, (1,)).astype(jnp.float32),
            jnp.reshape(tau_arp, (1,)).astype(jnp.float32),
            jnp.reshape(dt, (1,)).astype(jnp.float32),
        )
    )
