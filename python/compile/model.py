"""L2 JAX model: the neuron-dynamics compute graph built on the L1
Pallas kernels.

`lif_step` is the per-timestep entry point the Rust engine executes via
PJRT (one artifact per batch size, see aot.py). `lif_scan` chains T
steps with `lax.scan` — it demonstrates that the kernel composes under
jax transformations (XLA fuses the surrounding scan plumbing around the
pallas-emitted HLO), is used by the L2 tests, and is exported as an
artifact for the multi-step ablation bench.

Python here runs at build time only; the request path is pure Rust.
"""

import jax
import jax.numpy as jnp

from compile.kernels import conn_prob as _conn
from compile.kernels import lif_step as _lif


def lif_step(v, c, refr, j, em, ec, kf, alpha, e_rest, v_theta, v_reset,
             tau_arp, dt):
    """One time-driven step for a cluster of neurons (L1 kernel)."""
    return _lif.lif_step(v, c, refr, j, em, ec, kf, alpha, e_rest, v_theta,
                         v_reset, tau_arp, dt)


def lif_scan(v, c, refr, j_seq, em, ec, kf, alpha, e_rest, v_theta, v_reset,
             tau_arp, dt):
    """T chained steps: j_seq is f32[T, N] of per-step currents.

    Returns the final (v, c, refr) plus the f32[T, N] spike raster.
    """

    def body(carry, j_t):
        v, c, refr = carry
        v, c, refr, spike = lif_step(v, c, refr, j_t, em, ec, kf, alpha,
                                     e_rest, v_theta, v_reset, tau_arp, dt)
        return (v, c, refr), spike

    (v, c, refr), spikes = jax.lax.scan(body, (v, c, refr), j_seq)
    return v, c, refr, spikes


def conn_prob_gaussian(dx, dy, amplitude, sigma_um, spacing_um, cutoff):
    """Fig. 2 field, Gaussian rule (L1 kernel)."""
    return _conn.conn_prob(dx, dy, amplitude, sigma_um, spacing_um, cutoff,
                           rule="gaussian")


def conn_prob_exponential(dx, dy, amplitude, lambda_um, spacing_um, cutoff):
    """Fig. 2 field, exponential rule (L1 kernel)."""
    return _conn.conn_prob(dx, dy, amplitude, lambda_um, spacing_um, cutoff,
                           rule="exponential")


def neuron_constants(tau_m_ms, tau_c_ms, g_tilde, dt_ms):
    """Per-population integration constants (mirrors LifParams in Rust).

    Returns (em, ec, kf) scalars: em = exp(-dt/tau_m), ec = exp(-dt/tau_c),
    kf = g_tilde / (1/tau_m - 1/tau_c).
    """
    em = jnp.exp(-dt_ms / tau_m_ms)
    ec = jnp.exp(-dt_ms / tau_c_ms)
    denom = jnp.asarray(1.0 / tau_m_ms - 1.0 / tau_c_ms)
    degenerate = jnp.abs(denom) < 1e-12
    safe = jnp.where(degenerate, 1.0, denom)
    kf = jnp.where(degenerate, 0.0, g_tilde / safe)
    return em, ec, kf
