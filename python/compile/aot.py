"""AOT compiler: lower the L2 model (wrapping the L1 Pallas kernels) to
HLO-text artifacts the Rust runtime loads via PJRT.

Interchange is HLO *text*, NOT ``lowered.compile().serialize()``: jax
>= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py there).

Artifacts (written to --out-dir, default ../artifacts):
  lif_step_{N}.hlo.txt        N in BATCH_SIZES — per-step neuron update
  lif_scan_{T}x{N}.hlo.txt    multi-step scan (ablation bench)
  conn_field_{rule}.hlo.txt   Fig. 2 probability field kernels

Run once via ``make artifacts``; a stamp file short-circuits rebuilds.
"""

import argparse
import hashlib
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Must match rust/src/runtime/batch.rs::BATCH_SIZES.
BATCH_SIZES = [1024, 4096, 16384, 65536]
SCAN_SHAPE = (16, 4096)  # (T, N) for the scan artifact
CONN_BATCH = 1024


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_lif_step(n: int) -> str:
    arr = f32(n)
    scalar = f32()
    lowered = jax.jit(model.lif_step).lower(
        arr, arr, arr, arr, arr, arr, arr, arr,
        scalar, scalar, scalar, scalar, scalar,
    )
    return to_hlo_text(lowered)


def lower_lif_scan(t: int, n: int) -> str:
    arr = f32(n)
    scalar = f32()
    lowered = jax.jit(model.lif_scan).lower(
        arr, arr, arr, f32(t, n), arr, arr, arr, arr,
        scalar, scalar, scalar, scalar, scalar,
    )
    return to_hlo_text(lowered)


def lower_conn(rule: str, n: int) -> str:
    arr = f32(n)
    scalar = f32()
    fn = (model.conn_prob_gaussian if rule == "gaussian"
          else model.conn_prob_exponential)
    lowered = jax.jit(fn).lower(arr, arr, scalar, scalar, scalar, scalar)
    return to_hlo_text(lowered)


def build_all(out_dir: pathlib.Path, verbose: bool = True) -> dict:
    """Lower every artifact; returns {name: sha256} for the stamp."""
    out_dir.mkdir(parents=True, exist_ok=True)
    artifacts = {}

    def emit(name: str, text: str):
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        artifacts[name] = digest
        if verbose:
            print(f"  {path}  ({len(text) / 1024:.0f} KiB, {digest})")

    for n in BATCH_SIZES:
        emit(f"lif_step_{n}", lower_lif_step(n))
    t, n = SCAN_SHAPE
    emit(f"lif_scan_{t}x{n}", lower_lif_scan(t, n))
    for rule in ("gaussian", "exponential"):
        emit(f"conn_field_{rule}", lower_conn(rule, CONN_BATCH))
    return artifacts


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    print(f"lowering artifacts to {out_dir.resolve()}")
    artifacts = build_all(out_dir)
    stamp = out_dir / "MANIFEST.txt"
    stamp.write_text(
        "".join(f"{name} {digest}\n" for name, digest in sorted(artifacts.items()))
    )
    print(f"wrote {len(artifacts)} artifacts + MANIFEST.txt")
    return 0


if __name__ == "__main__":
    sys.exit(main())
